"""Model zoo: dense GQA transformer, MoE, VLM, xLSTM, Whisper, Zamba2."""

from repro.models.model_zoo import (
    build_model,
    decode_input_specs,
    input_specs,
    prefill_input_specs,
    train_input_specs,
)
from repro.models.transformer import DecoderLM, ModelOptions
from repro.models.whisper import WhisperLM
from repro.models.xlstm import XLSTMLM
from repro.models.zamba import ZambaLM

__all__ = [
    "build_model", "input_specs", "train_input_specs", "prefill_input_specs",
    "decode_input_specs", "DecoderLM", "ModelOptions", "WhisperLM", "XLSTMLM",
    "ZambaLM",
]
