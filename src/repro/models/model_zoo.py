"""Model registry: ``build_model(cfg)`` dispatch + ShapeDtypeStruct input
specs for every (arch x shape) dry-run cell.

``input_specs`` follows the shannon/kernels pattern: weak-type-correct,
shardable stand-ins with zero device allocation -- ``jax.eval_shape`` over
``init_cache`` supplies decode-cache structure.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeSpec
from repro.models.transformer import DecoderLM, ModelOptions
from repro.models.whisper import N_FRAMES, WhisperLM
from repro.models.xlstm import XLSTMLM
from repro.models.zamba import ZambaLM


def build_model(cfg: ArchConfig, opts: ModelOptions | None = None):
    family = cfg.family
    if family in ("dense", "moe", "vlm"):
        return DecoderLM(cfg, opts)
    if family == "ssm":
        return XLSTMLM(cfg, opts)
    if family == "audio":
        return WhisperLM(cfg, opts)
    if family == "hybrid":
        return ZambaLM(cfg, opts)
    raise ValueError(f"unknown family {family!r}")


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def train_input_specs(cfg: ArchConfig, shape: ShapeSpec, opts: ModelOptions | None = None):
    """Batch stand-ins for ``train_step`` / prefill forward."""
    opts = opts or ModelOptions()
    b, s = shape.global_batch, shape.seq_len
    specs = {
        "tokens": _sds((b, s), jnp.int32),
        "labels": _sds((b, s), jnp.int32),
    }
    if cfg.family == "vlm":
        specs["patches"] = _sds((b, cfg.n_patches, cfg.d_model), opts.cdt)
    if cfg.family == "audio":
        specs["frames"] = _sds((b, N_FRAMES, cfg.d_model), opts.cdt)
    return specs


def prefill_input_specs(cfg: ArchConfig, shape: ShapeSpec, opts: ModelOptions | None = None):
    specs = train_input_specs(cfg, shape, opts)
    specs.pop("labels")
    return specs


def decode_input_specs(cfg: ArchConfig, shape: ShapeSpec, opts: ModelOptions | None = None):
    """(tokens, cache) stand-ins for ``serve_step``: one new token against a
    KV cache / recurrent state sized for ``shape.seq_len``."""
    model = build_model(cfg, opts)
    b = shape.global_batch
    cache = jax.eval_shape(lambda: model.init_cache(b, shape.seq_len))
    return {"tokens": _sds((b, 1), jnp.int32), "cache": cache}


def input_specs(cfg: ArchConfig, shape: ShapeSpec, opts: ModelOptions | None = None):
    if shape.kind == "train":
        return train_input_specs(cfg, shape, opts)
    if shape.kind == "prefill":
        return prefill_input_specs(cfg, shape, opts)
    if shape.kind == "decode":
        return decode_input_specs(cfg, shape, opts)
    raise ValueError(shape.kind)
