"""xLSTM LM: mLSTM (matrix memory, parallelizable) + sLSTM (scalar memory,
strictly recurrent) blocks in a repeating unit [mLSTM x (k-1), sLSTM x 1]
(arXiv:2405.04517).

The gating math is the paper's stabilized exponential form (max-stabilizer
``m_t``).  Block plumbing is simplified to a uniform pre-up-projection
structure (see DESIGN.md §4); recurrences are ``lax.scan`` over time --
decode state is O(1) in sequence length, so this arch runs ``long_500k``.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.parallel.sharding import lshard


def _proj_init(key, shape, dtype):
    return L.dense_init(key, shape, dtype=dtype)


def _mask_padded_vocab(logits, cfg):
    if cfg.padded_vocab == cfg.vocab:
        return logits
    valid = jnp.arange(cfg.padded_vocab) < cfg.vocab
    return jnp.where(valid[None, None, :], logits, -1e30)


def segmented_scan(f, init, xs, seg_len: int = 128):
    """lax.scan with gradient checkpointing every ``seg_len`` steps: AD saves
    only segment-boundary carries (O(s/seg) instead of O(s) carry copies --
    essential for the (b,H,dh,dh) mLSTM matrix memory at seq 4k+)."""
    s = jax.tree.leaves(xs)[0].shape[0]
    if seg_len >= s or s % seg_len:
        return jax.lax.scan(f, init, xs)
    n_seg = s // seg_len
    xs_seg = jax.tree.map(lambda a: a.reshape((n_seg, seg_len) + a.shape[1:]), xs)

    @functools.partial(jax.checkpoint, prevent_cse=False)
    def seg_body(carry, xseg):
        return jax.lax.scan(f, carry, xseg)

    carry, ys = jax.lax.scan(seg_body, init, xs_seg)
    ys = jax.tree.map(lambda a: a.reshape((s,) + a.shape[2:]), ys)
    return carry, ys


# ---------------------------------------------------------------- mLSTM cell
def init_mlstm(key, d_model, d_in, n_heads, dtype):
    ks = jax.random.split(key, 8)
    dh = d_in // n_heads
    return {
        "ssm": {
            "w_in": _proj_init(ks[0], (d_model, 2 * d_in), dtype),   # x branch + gate z
            "w_q": _proj_init(ks[1], (d_in, d_in), dtype),
            "w_k": _proj_init(ks[2], (d_in, d_in), dtype),
            "w_v": _proj_init(ks[3], (d_in, d_in), dtype),
            "w_i": _proj_init(ks[4], (d_in, n_heads), dtype),
            "w_f": _proj_init(ks[5], (d_in, n_heads), dtype),
            "w_out": _proj_init(ks[6], (d_in, d_model), dtype),
            "f_bias": jnp.full((n_heads,), 3.0, dtype),  # open forget gates at init
        },
        "norm": L.init_rmsnorm(d_model, dtype),
    }


def mlstm_state(batch, n_heads, dh, dtype=jnp.float32):
    return {
        "C": jnp.zeros((batch, n_heads, dh, dh), dtype),
        "n": jnp.zeros((batch, n_heads, dh), dtype),
        "m": jnp.full((batch, n_heads), -1e30, dtype),
    }


def _mlstm_step(state, qkv_ifg):
    """One stabilized mLSTM step.  q,k,v: (b,H,dh); i,f: (b,H) raw logits."""
    q, k, v, i_raw, f_raw = qkv_ifg
    C, n, m = state["C"], state["n"], state["m"]
    logf = jax.nn.log_sigmoid(f_raw)                  # sigmoid forget gate
    m_new = jnp.maximum(logf + m, i_raw)
    i_p = jnp.exp(i_raw - m_new)
    f_p = jnp.exp(logf + m - m_new)
    C_new = f_p[..., None, None] * C + i_p[..., None, None] * (
        v[..., :, None] * k[..., None, :]
    )                                                  # (b,H,dh,dh): v outer k
    n_new = f_p[..., None] * n + i_p[..., None] * k
    denom = jnp.maximum(
        jnp.abs(jnp.einsum("bhd,bhd->bh", n_new, q)), jnp.exp(-m_new)
    )
    h = jnp.einsum("bhij,bhj->bhi", C_new, q) / denom[..., None]
    return {"C": C_new, "n": n_new, "m": m_new}, h


def mlstm_fwd(params, x, state, eps):
    """x: (b, s, d) -> (y, new_state); scan over time."""
    p = params["ssm"]
    cd = x.dtype
    b, s, d = x.shape
    H = p["w_i"].shape[-1]
    xn = L.rmsnorm(params["norm"], x, eps)
    xz = jnp.einsum("bsd,de->bse", xn, p["w_in"].astype(cd))
    xm, z = jnp.split(xz, 2, axis=-1)
    d_in = xm.shape[-1]
    dh = d_in // H
    q = jnp.einsum("bse,ef->bsf", xm, p["w_q"].astype(cd)).reshape(b, s, H, dh)
    k = jnp.einsum("bse,ef->bsf", xm, p["w_k"].astype(cd)).reshape(b, s, H, dh) / np.sqrt(dh)
    v = jnp.einsum("bse,ef->bsf", xm, p["w_v"].astype(cd)).reshape(b, s, H, dh)
    i_raw = jnp.einsum("bse,eh->bsh", xm, p["w_i"].astype(cd)).astype(jnp.float32)
    f_raw = (
        jnp.einsum("bse,eh->bsh", xm, p["w_f"].astype(cd)).astype(jnp.float32)
        + p["f_bias"].astype(jnp.float32)
    )
    q = lshard(q, "batch", "seq", "heads", None)
    k = lshard(k, "batch", "seq", "heads", None)
    v = lshard(v, "batch", "seq", "heads", None)

    def step(st, inp):
        st, h = _mlstm_step(st, inp)
        return st, h

    xs = (
        q.swapaxes(0, 1).astype(jnp.float32),
        k.swapaxes(0, 1).astype(jnp.float32),
        v.swapaxes(0, 1).astype(jnp.float32),
        i_raw.swapaxes(0, 1),
        f_raw.swapaxes(0, 1),
    )
    state, hs = segmented_scan(step, state, xs)         # hs: (s, b, H, dh)
    h = hs.swapaxes(0, 1).reshape(b, s, d_in).astype(cd)
    h = h * jax.nn.silu(z.astype(jnp.float32)).astype(cd)
    y = jnp.einsum("bse,ed->bsd", h, p["w_out"].astype(cd))
    return y, state


# ---------------------------------------------------------------- sLSTM cell
def init_slstm(key, d_model, d_in, n_heads, dtype):
    ks = jax.random.split(key, 7)
    dh = d_in // n_heads
    return {
        "ssm": {
            "w_in": _proj_init(ks[0], (d_model, d_in), dtype),
            "w_gates": _proj_init(ks[1], (d_in, 4 * d_in), dtype),     # i,f,z,o
            "r_gates": _proj_init(ks[2], (n_heads, dh, 4 * dh), dtype),  # per-head recurrent
            "w_out": _proj_init(ks[3], (d_in, d_model), dtype),
            "f_bias": jnp.full((d_in,), 3.0, dtype),
        },
        "norm": L.init_rmsnorm(d_model, dtype),
    }


def slstm_state(batch, n_heads, dh, dtype=jnp.float32):
    return {
        "c": jnp.zeros((batch, n_heads, dh), dtype),
        "n": jnp.ones((batch, n_heads, dh), dtype),
        "m": jnp.zeros((batch, n_heads, dh), dtype),
        "h": jnp.zeros((batch, n_heads, dh), dtype),
    }


def _slstm_step(p, state, xg, H, dh):
    """xg: (b, 4*d_in) pre-activation gates from the input path."""
    c, n, m, h_prev = state["c"], state["n"], state["m"], state["h"]
    b = xg.shape[0]
    rec = jnp.einsum("bhd,hdg->bhg", h_prev, p["r_gates"].astype(h_prev.dtype))
    gates = xg.reshape(b, H, 4 * dh) + rec
    i_raw, f_raw, z_raw, o_raw = jnp.split(gates.astype(jnp.float32), 4, axis=-1)
    logf = jax.nn.log_sigmoid(f_raw)
    m_new = jnp.maximum(logf + m, i_raw)
    i_p = jnp.exp(i_raw - m_new)
    f_p = jnp.exp(logf + m - m_new)
    c_new = f_p * c + i_p * jnp.tanh(z_raw)
    n_new = f_p * n + i_p
    h_new = jax.nn.sigmoid(o_raw) * c_new / jnp.maximum(n_new, 1e-6)
    return {"c": c_new, "n": n_new, "m": m_new, "h": h_new}


def slstm_fwd(params, x, state, eps):
    p = params["ssm"]
    cd = x.dtype
    b, s, d = x.shape
    H, dh, _ = p["r_gates"].shape
    xn = L.rmsnorm(params["norm"], x, eps)
    xi = jnp.einsum("bsd,de->bse", xn, p["w_in"].astype(cd))
    xg = jnp.einsum("bse,eg->bsg", xi, p["w_gates"].astype(cd))
    # only the f-gate block receives the (open-at-init) bias
    d_in = H * dh
    bias = jnp.zeros((4 * d_in,), cd).at[d_in : 2 * d_in].set(p["f_bias"].astype(cd))
    xg = xg + bias

    def step(st, xg_t):
        st = _slstm_step(p, st, xg_t, H, dh)
        return st, st["h"]

    state, hs = segmented_scan(step, state, xg.swapaxes(0, 1))
    h = hs.swapaxes(0, 1).reshape(b, s, d_in).astype(cd)
    y = jnp.einsum("bse,ed->bsd", h, p["w_out"].astype(cd))
    return y, state


# ------------------------------------------------------------------ full LM
class XLSTMLM:
    """Repeating unit of (slstm_every-1) mLSTM blocks + 1 sLSTM block,
    scanned over units."""

    def __init__(self, cfg: ArchConfig, opts=None):
        from repro.models.transformer import ModelOptions

        self.cfg = cfg
        self.opts = opts or ModelOptions()
        if cfg.n_layers % cfg.slstm_every:
            raise ValueError("n_layers must be divisible by slstm_every")
        self.n_units = cfg.n_layers // cfg.slstm_every
        self.m_per_unit = cfg.slstm_every - 1
        self.d_in = cfg.ssm_expand * cfg.d_model

    @property
    def dh(self):
        return self.d_in // self.cfg.n_heads

    def _init_unit(self, key):
        cfg, pdt = self.cfg, self.opts.pdt
        ks = jax.random.split(key, self.m_per_unit + 1)
        m_params = jax.vmap(
            lambda k: init_mlstm(k, cfg.d_model, self.d_in, cfg.n_heads, pdt)
        )(ks[: self.m_per_unit])
        s_params = init_slstm(ks[-1], cfg.d_model, self.d_in, cfg.n_heads, pdt)
        return {"mlstm": m_params, "slstm": s_params}

    def init(self, key):
        cfg, pdt = self.cfg, self.opts.pdt
        k_emb, k_units, k_head = jax.random.split(key, 3)
        unit_keys = jax.random.split(k_units, self.n_units)
        return {
            "embed": {"tokens": L.dense_init(k_emb, (cfg.padded_vocab, cfg.d_model), dtype=pdt)},
            "units": jax.vmap(self._init_unit)(unit_keys),
            "final_norm": L.init_rmsnorm(cfg.d_model, pdt),
            "lm_head": L.dense_init(k_head, (cfg.d_model, cfg.padded_vocab), dtype=pdt),
        }

    def _zero_states(self, batch):
        cfg = self.cfg
        m_st = mlstm_state(batch, cfg.n_heads, self.dh)
        s_st = slstm_state(batch, cfg.n_heads, self.dh)
        stack_m = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (self.n_units, self.m_per_unit) + a.shape), m_st
        )
        stack_s = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (self.n_units,) + a.shape), s_st
        )
        return {"mlstm": stack_m, "slstm": stack_s}

    def _unit_fwd(self, up, x, m_states, s_state):
        cfg = self.cfg

        def m_body(x, inp):
            lp, st = inp
            y, st = mlstm_fwd(lp, x, st, cfg.norm_eps)
            return x + y, st

        x, m_states = jax.lax.scan(m_body, x, (up["mlstm"], m_states))
        y, s_state = slstm_fwd(up["slstm"], x, s_state, cfg.norm_eps)
        return x + y, m_states, s_state

    def forward(self, params, batch):
        cfg, cd = self.cfg, self.opts.cdt
        tokens = batch["tokens"]
        x = params["embed"]["tokens"].astype(cd)[tokens]
        x = lshard(x, "batch", "seq", "embed")
        states = self._zero_states(tokens.shape[0])

        def body(x, inp):
            up, m_st, s_st = inp
            fn = self._unit_fwd
            if self.opts.remat:
                fn = jax.checkpoint(fn, prevent_cse=False)
            x, m_st, s_st = fn(up, x, m_st, s_st)
            return x, None

        x, _ = jax.lax.scan(body, x, (params["units"], states["mlstm"], states["slstm"]))
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(cd))
        logits = _mask_padded_vocab(logits, cfg)
        return lshard(logits, "batch", "seq", "vocab"), jnp.zeros((), jnp.float32)

    def loss(self, params, batch):
        from repro.models.transformer import DecoderLM

        logits, aux = self.forward(params, batch)
        labels = batch["labels"]
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        mask = (labels >= 0).astype(jnp.float32)
        safe = jnp.maximum(labels, 0)
        nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
        denom = jnp.maximum(mask.sum(), 1.0)
        return (nll * mask).sum() / denom, {"ce": (nll * mask).sum() / denom, "aux": aux, "tokens": denom}

    # ----------------------------------------------------------------- serve
    def init_cache(self, batch: int, max_len: int):
        del max_len  # recurrent: O(1) state
        return {"states": self._zero_states(batch), "index": jnp.zeros((), jnp.int32)}

    def cache_axes(self) -> dict:
        m = {
            "C": ("units", "per_unit", "batch", "heads", None, None),
            "n": ("units", "per_unit", "batch", "heads", None),
            "m": ("units", "per_unit", "batch", "heads"),
        }
        s = {k: ("units", "batch", "heads", None) for k in ("c", "n", "m", "h")}
        return {"states": {"mlstm": m, "slstm": s}, "index": ()}

    def decode_step(self, params, cache, tokens):
        cfg, cd = self.cfg, self.opts.cdt
        x = params["embed"]["tokens"].astype(cd)[tokens]  # (b, 1, d)
        states = cache["states"]

        def unit_body(x, inp):
            up, m_st, s_st = inp

            def m_body(x, inp2):
                lp, st = inp2
                y, st = mlstm_fwd(lp, x, st, cfg.norm_eps)
                return x + y, st

            x, m_st = jax.lax.scan(m_body, x, (up["mlstm"], m_st))
            y, s_st = slstm_fwd(up["slstm"], x, s_st, cfg.norm_eps)
            return x + y, (m_st, s_st)

        x, (m_sts, s_sts) = jax.lax.scan(
            unit_body, x, (params["units"], states["mlstm"], states["slstm"])
        )
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = _mask_padded_vocab(
            jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(cd)), cfg)
        return logits, {
            "states": {"mlstm": m_sts, "slstm": s_sts},
            "index": cache["index"] + 1,
        }
