"""Whisper-style encoder-decoder ASR backbone (arXiv:2212.04356).

The conv/mel frontend is a STUB per the assignment: ``input_specs()``
provides precomputed frame embeddings (b, n_frames, d_model); a single
linear adapter ("frame_proj") stands in for the conv stack.  Positions are
sinusoidal for both stacks (the original uses sinusoidal encoder / learned
decoder positions; learned tables don't extend to the 32k decode shape --
deviation noted in DESIGN.md).  MLPs are 2-layer GELU as in the original.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.parallel.sharding import lshard

N_FRAMES = 1500  # whisper's 30 s window after the conv stack


def _mask_padded(logits, cfg):
    if cfg.padded_vocab == cfg.vocab:
        return logits
    valid = jnp.arange(cfg.padded_vocab) < cfg.vocab
    return jnp.where(valid[None, None, :], logits, -1e30)


def sinusoid_pos(seq_len: int, d_model: int, offset=0):
    pos = jnp.arange(seq_len, dtype=jnp.float32)[:, None] + offset
    dim = jnp.arange(0, d_model, 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, dim / d_model)
    pe = jnp.zeros((seq_len, d_model))
    pe = pe.at[:, 0::2].set(jnp.sin(ang)).at[:, 1::2].set(jnp.cos(ang))
    return pe


def init_gelu_mlp(key, d_model, d_ff, dtype):
    ki, ko = jax.random.split(key)
    return {
        "mlp": {
            "w_in": L.dense_init(ki, (d_model, d_ff), dtype=dtype),
            "w_out": L.dense_init(ko, (d_ff, d_model), dtype=dtype),
        }
    }


def gelu_mlp_fwd(p, x):
    cd = x.dtype
    h = jnp.einsum("bsd,df->bsf", x, p["mlp"]["w_in"].astype(cd))
    h = lshard(h, "batch", "seq", "ffn")
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(cd)
    return jnp.einsum("bsf,fd->bsd", h, p["mlp"]["w_out"].astype(cd))


class WhisperLM:
    def __init__(self, cfg: ArchConfig, opts=None):
        from repro.models.transformer import ModelOptions

        self.cfg = cfg
        self.opts = opts or ModelOptions()

    # ------------------------------------------------------------------ init
    def _init_enc_layer(self, key):
        cfg, pdt = self.cfg, self.opts.pdt
        ka, km = jax.random.split(key)
        return {
            "attn": L.init_attention(ka, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                     cfg.resolved_head_dim, dtype=pdt),
            "attn_norm": L.init_rmsnorm(cfg.d_model, pdt),
            "ffn_norm": L.init_rmsnorm(cfg.d_model, pdt),
            **init_gelu_mlp(km, cfg.d_model, cfg.d_ff, pdt),
        }

    def _init_dec_layer(self, key):
        cfg, pdt = self.cfg, self.opts.pdt
        ka, kx, km = jax.random.split(key, 3)
        return {
            "attn": L.init_attention(ka, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                     cfg.resolved_head_dim, dtype=pdt),
            "attn_norm": L.init_rmsnorm(cfg.d_model, pdt),
            "xattn": L.init_attention(kx, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                      cfg.resolved_head_dim, dtype=pdt),
            "xattn_norm": L.init_rmsnorm(cfg.d_model, pdt),
            "ffn_norm": L.init_rmsnorm(cfg.d_model, pdt),
            **init_gelu_mlp(km, cfg.d_model, cfg.d_ff, pdt),
        }

    def init(self, key):
        cfg, pdt = self.cfg, self.opts.pdt
        ke, kd, kemb, kf = jax.random.split(key, 4)
        enc_keys = jax.random.split(ke, cfg.n_encoder_layers)
        dec_keys = jax.random.split(kd, cfg.n_layers)
        return {
            "embed": {"tokens": L.dense_init(kemb, (cfg.padded_vocab, cfg.d_model), dtype=pdt)},
            "frame_proj": L.dense_init(kf, (cfg.d_model, cfg.d_model), dtype=pdt),
            "enc_layers": jax.vmap(self._init_enc_layer)(enc_keys),
            "dec_layers": jax.vmap(self._init_dec_layer)(dec_keys),
            "enc_norm": L.init_rmsnorm(cfg.d_model, pdt),
            "final_norm": L.init_rmsnorm(cfg.d_model, pdt),
            # whisper ties the output head to the token embedding
        }

    # --------------------------------------------------------------- encoder
    def encode(self, params, frames):
        cfg, cd = self.cfg, self.opts.cdt
        x = jnp.einsum("bsd,de->bse", frames.astype(cd), params["frame_proj"].astype(cd))
        x = x + sinusoid_pos(x.shape[1], cfg.d_model).astype(cd)[None]
        x = lshard(x, "batch", "seq", "embed")
        positions = jnp.arange(x.shape[1])[None, :]

        def body(x, lp):
            h = L.attention_fwd(
                lp["attn"], L.rmsnorm(lp["attn_norm"], x, cfg.norm_eps), positions,
                n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                head_dim=cfg.resolved_head_dim, causal=False, use_rope=False,
            )
            x = x + h
            x = x + gelu_mlp_fwd(lp, L.rmsnorm(lp["ffn_norm"], x, cfg.norm_eps))
            return x, None

        if self.opts.remat:
            body = jax.checkpoint(body, prevent_cse=False)
        x, _ = jax.lax.scan(body, x, params["enc_layers"])
        return L.rmsnorm(params["enc_norm"], x, cfg.norm_eps)

    # --------------------------------------------------------------- decoder
    def _cross_kv(self, lp, enc_out):
        cfg, cd = self.cfg, self.opts.cdt
        b, se, _ = enc_out.shape
        hd, K = cfg.resolved_head_dim, cfg.n_kv_heads
        k = jnp.einsum("bsd,dh->bsh", enc_out, lp["xattn"]["wk"].astype(cd)).reshape(b, se, K, hd)
        v = jnp.einsum("bsd,dh->bsh", enc_out, lp["xattn"]["wv"].astype(cd)).reshape(b, se, K, hd)
        return k, v

    def decode_stack(self, params, tokens, enc_out):
        cfg, cd = self.cfg, self.opts.cdt
        x = params["embed"]["tokens"].astype(cd)[tokens]
        x = x + sinusoid_pos(x.shape[1], cfg.d_model).astype(cd)[None]
        x = lshard(x, "batch", "seq", "embed")
        positions = jnp.arange(x.shape[1])[None, :]

        def body(x, lp):
            h = L.attention_fwd(
                lp["attn"], L.rmsnorm(lp["attn_norm"], x, cfg.norm_eps), positions,
                n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                head_dim=cfg.resolved_head_dim, causal=True, use_rope=False,
                attn_impl=self.opts.attn_impl, chunk=self.opts.attn_chunk,
            )
            x = x + h
            kv = self._cross_kv(lp, enc_out)
            h = L.attention_fwd(
                lp["xattn"], L.rmsnorm(lp["xattn_norm"], x, cfg.norm_eps), positions,
                n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                head_dim=cfg.resolved_head_dim, causal=False, use_rope=False,
                kv_override=kv,
            )
            x = x + h
            x = x + gelu_mlp_fwd(lp, L.rmsnorm(lp["ffn_norm"], x, cfg.norm_eps))
            return x, None

        if self.opts.remat:
            body = jax.checkpoint(body, prevent_cse=False)
        x, _ = jax.lax.scan(body, x, params["dec_layers"])
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = jnp.einsum("bsd,dv->bsv", x, params["embed"]["tokens"].T.astype(cd))
        return _mask_padded(logits, cfg)

    def forward(self, params, batch):
        enc_out = self.encode(params, batch["frames"])
        logits = self.decode_stack(params, batch["tokens"], enc_out)
        return lshard(logits, "batch", "seq", "vocab"), jnp.zeros((), jnp.float32)

    def loss(self, params, batch):
        logits, aux = self.forward(params, batch)
        labels = batch["labels"]
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        mask = (labels >= 0).astype(jnp.float32)
        nll = -jnp.take_along_axis(logp, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
        denom = jnp.maximum(mask.sum(), 1.0)
        ce = (nll * mask).sum() / denom
        return ce, {"ce": ce, "aux": aux, "tokens": denom}

    # ----------------------------------------------------------------- serve
    def init_cache(self, batch: int, max_len: int, n_frames: int = N_FRAMES):
        cfg = self.cfg
        cd = self.opts.cdt
        hd, K, nl = cfg.resolved_head_dim, cfg.n_kv_heads, cfg.n_layers
        kv = L.init_kv_cache(batch, max_len, K, hd, dtype=cd)
        return {
            "kv": jax.tree.map(lambda a: jnp.broadcast_to(a, (nl,) + a.shape), kv),
            "cross_k": jnp.zeros((nl, batch, n_frames, K, hd), cd),
            "cross_v": jnp.zeros((nl, batch, n_frames, K, hd), cd),
            "index": jnp.zeros((), jnp.int32),
        }

    def cache_axes(self) -> dict:
        kv = ("layers", "batch", "kv_seq", "kv_heads", "head_dim")
        cross = ("layers", "batch", None, "kv_heads", "head_dim")
        return {"kv": {"k": kv, "v": kv}, "cross_k": cross, "cross_v": cross,
                "index": ()}

    def prefill_cross(self, params, cache, frames):
        """Run the encoder once and fill the cross-attention KV cache."""
        enc_out = self.encode(params, frames)

        def per_layer(lp):
            k, v = self._cross_kv(lp, enc_out)
            return k, v

        ks, vs = jax.vmap(per_layer)(params["dec_layers"])
        return {**cache, "cross_k": ks.astype(cache["cross_k"].dtype),
                "cross_v": vs.astype(cache["cross_v"].dtype)}

    def decode_step(self, params, cache, tokens):
        cfg, cd = self.cfg, self.opts.cdt
        x = params["embed"]["tokens"].astype(cd)[tokens]
        index = cache["index"]
        x = x + sinusoid_pos(1, cfg.d_model, offset=index).astype(cd)[None]

        def body(x, inp):
            lp, kvc, ck, cv = inp
            h, kvc = L.attention_decode(
                lp["attn"], L.rmsnorm(lp["attn_norm"], x, cfg.norm_eps), kvc, index,
                n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                head_dim=cfg.resolved_head_dim, use_rope=False,
            )
            x = x + h
            # cross attention over the (precomputed) encoder KV
            b = x.shape[0]
            xn = L.rmsnorm(lp["xattn_norm"], x, cfg.norm_eps)
            q = jnp.einsum("bsd,dh->bsh", xn, lp["xattn"]["wq"].astype(cd)).reshape(
                b, 1, cfg.n_heads, cfg.resolved_head_dim
            )
            k = L._repeat_kv(ck.astype(cd), cfg.n_heads // cfg.n_kv_heads)
            v = L._repeat_kv(cv.astype(cd), cfg.n_heads // cfg.n_kv_heads)
            mask = jnp.ones((1, 1, 1, k.shape[1]), bool)
            h = L.attention_scores(q, k, v, mask, compute_dtype=cd).reshape(
                b, 1, cfg.n_heads * cfg.resolved_head_dim
            )
            x = x + jnp.einsum("bsh,hd->bsd", h, lp["xattn"]["wo"].astype(cd))
            x = x + gelu_mlp_fwd(lp, L.rmsnorm(lp["ffn_norm"], x, cfg.norm_eps))
            return x, kvc

        x, kv = jax.lax.scan(
            body, x, (params["dec_layers"], cache["kv"], cache["cross_k"], cache["cross_v"])
        )
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = _mask_padded(
            jnp.einsum("bsd,dv->bsv", x, params["embed"]["tokens"].T.astype(cd)), cfg)
        return logits, {**cache, "kv": kv, "index": index + 1}
