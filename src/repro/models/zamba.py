"""Zamba2-style hybrid LM: Mamba2 (SSD) backbone + one *shared* attention
block applied every ``attn_every`` layers (arXiv:2411.15242).

Mamba2 blocks use the SSD recurrence with scalar-per-head decay:
    S_t = a_t * S_{t-1} + dt_t * (x_t outer B_t),   y_t = S_t C_t + D x_t
with a short depthwise causal conv on the (x, B, C) path.  Training uses a
chunkwise-parallel scan (intra-chunk attention-like matmuls + inter-chunk
state recurrence), decode a single recurrent step -- O(1) state, so this
arch runs ``long_500k``.  The shared attention uses a ring-buffer KV cache
capped at ``cfg.long_context_window`` during decode.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models.xlstm import _mask_padded_vocab
from repro.parallel.sharding import lshard

CONV_K = 4  # depthwise conv window (mamba2 default)


# -------------------------------------------------------------- mamba2 block
def init_mamba2(key, d_model, d_in, n_heads, d_state, dtype):
    ks = jax.random.split(key, 6)
    P = d_in // n_heads
    conv_dim = d_in + 2 * d_state
    return {
        "ssm": {
            # in_proj -> [z (d_in), x (d_in), B (N), C (N), dt (H)]
            "w_in": L.dense_init(ks[0], (d_model, 2 * d_in + 2 * d_state + n_heads), dtype=dtype),
            "conv_w": (jax.random.normal(ks[1], (CONV_K, conv_dim), jnp.float32) * 0.1).astype(dtype),
            "A_log": jnp.log(jnp.linspace(1.0, float(n_heads), n_heads)).astype(dtype),
            "D": jnp.ones((n_heads,), dtype),
            "dt_bias": jnp.log(jnp.expm1(jnp.full((n_heads,), 0.01))).astype(dtype),
            "w_out": L.dense_init(ks[2], (d_in, d_model), dtype=dtype),
        },
        "norm": L.init_rmsnorm(d_model, dtype),
    }


def _causal_conv(x, w, tail=None):
    """Depthwise causal conv.  x: (b, s, c), w: (K, c); ``tail`` (b, K-1, c)
    supplies the preceding raw inputs for streaming decode (zeros at t=0)."""
    K = w.shape[0]
    if tail is None:
        full = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        full = jnp.concatenate([tail.astype(x.dtype), x], axis=1)
    out = sum(full[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(K))
    return out, full[:, -(K - 1) :, :]


def _ssd_split(p, x, cfg_heads, d_in, d_state, conv_tail=None):
    cd = x.dtype
    proj = jnp.einsum("bsd,de->bse", x, p["w_in"].astype(cd))
    z = proj[..., :d_in]
    xbc = proj[..., d_in : d_in + d_in + 2 * d_state]
    dt_raw = proj[..., -cfg_heads:]
    xbc, new_tail = _causal_conv(xbc, p["conv_w"].astype(cd), conv_tail)
    xbc = jax.nn.silu(xbc.astype(jnp.float32)).astype(cd)
    xc = xbc[..., :d_in]
    B = xbc[..., d_in : d_in + d_state]
    C = xbc[..., d_in + d_state :]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    return z, xc, B, C, dt, new_tail


def mamba2_fwd(params, x, eps, chunk: int = 128):
    """Chunkwise-parallel SSD over the full sequence (training / prefill)."""
    p = params["ssm"]
    cd = x.dtype
    b, s, d = x.shape
    H = p["A_log"].shape[0]
    d_in = p["w_out"].shape[0]
    d_state = (p["w_in"].shape[1] - 2 * d_in - H) // 2
    P = d_in // H

    xn = L.rmsnorm(params["norm"], x, eps)
    z, xc, B, C, dt, _ = _ssd_split(p, xn, H, d_in, d_state)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))            # (H,) negative
    xh = xc.reshape(b, s, H, P)
    xh = lshard(xh, "batch", "seq", "ssm_heads", None)
    loga = dt * A[None, None, :]                             # (b, s, H) log decay

    n_chunks = (s + chunk - 1) // chunk
    pad = n_chunks * chunk - s
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        loga = jnp.pad(loga, ((0, 0), (0, pad), (0, 0)))
    cs = chunk
    xhc = xh.reshape(b, n_chunks, cs, H, P).swapaxes(0, 1)   # (n, b, cs, H, P)
    Bc = B.reshape(b, n_chunks, cs, d_state).swapaxes(0, 1)
    Cc = C.reshape(b, n_chunks, cs, d_state).swapaxes(0, 1)
    dtc = dt.reshape(b, n_chunks, cs, H).swapaxes(0, 1)
    logac = loga.reshape(b, n_chunks, cs, H).swapaxes(0, 1)

    def chunk_body(S, inp):
        xck, Bk, Ck, dtk, logak = inp                        # (b, cs, ...)
        cum = jnp.cumsum(logak, axis=1)                      # (b, cs, H)
        total = cum[:, -1, :]                                # (b, H)
        # intra-chunk: y_intra[t] = sum_{u<=t} exp(cum_t - cum_u) dt_u (C_t.B_u) x_u
        decay = cum[:, :, None, :] - cum[:, None, :, :]      # (b, t, u, H)
        tri = jnp.tril(jnp.ones((cs, cs), bool))[None, :, :, None]
        gate = jnp.where(tri, jnp.exp(decay), 0.0)           # (b, t, u, H)
        cb = jnp.einsum("btn,bun->btu", Ck.astype(jnp.float32), Bk.astype(jnp.float32))
        w = gate * cb[..., None] * dtk[:, None, :, :]        # (b, t, u, H)
        xhc_f = xck.astype(jnp.float32)
        y_intra = jnp.einsum("btuh,buhp->bthp", w, xhc_f)
        # carried-in state contribution: y_state[t] = exp(cum_t) * (C_t . S)
        y_state = jnp.einsum("bhpn,btn->bthp", S, Ck.astype(jnp.float32))
        y_state = y_state * jnp.exp(cum)[:, :, :, None]      # (b,cs,H) -> bcast P
        y = y_intra + y_state
        # state update: S' = exp(total) S + sum_u exp(total - cum_u) dt_u x_u B_u^T
        w_state = jnp.exp(total[:, None, :] - cum) * dtk     # (b, cs, H)
        S_new = S * jnp.exp(total)[:, :, None, None] + jnp.einsum(
            "buh,buhp,bun->bhpn", w_state, xhc_f, Bk.astype(jnp.float32)
        )
        return S_new, y

    S0 = jnp.zeros((b, H, P, d_state), jnp.float32)
    _, ys = jax.lax.scan(chunk_body, S0, (xhc, Bc, Cc, dtc, logac))
    y = ys.swapaxes(0, 1).reshape(b, n_chunks * cs, H, P)[:, :s]
    y = y + xh[:, :s] * p["D"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(b, s, d_in).astype(cd)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(cd)
    return jnp.einsum("bse,ed->bsd", y, p["w_out"].astype(cd))


def mamba2_step(params, x, S, conv_tail, eps):
    """Single-token recurrent step.  x: (b, 1, d); S: (b, H, P, N);
    conv_tail: (b, CONV_K-1, conv_dim) raw pre-conv inputs of prior steps."""
    p = params["ssm"]
    cd = x.dtype
    b = x.shape[0]
    H = p["A_log"].shape[0]
    d_in = p["w_out"].shape[0]
    d_state = (p["w_in"].shape[1] - 2 * d_in - H) // 2
    P = d_in // H
    xn = L.rmsnorm(params["norm"], x, eps)
    z, xc, B, C, dt, conv_tail = _ssd_split(p, xn, H, d_in, d_state, conv_tail)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    a = jnp.exp(dt[:, 0, :] * A[None, :])                    # (b, H)
    xh = xc.reshape(b, H, P).astype(jnp.float32)
    S_new = S * a[:, :, None, None] + (dt[:, 0, :, None, None] * xh[..., None]) * B[
        :, 0, None, None, :
    ].astype(jnp.float32)
    y = jnp.einsum("bhpn,bn->bhp", S_new, C[:, 0].astype(jnp.float32))
    y = y + xh * p["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(b, 1, d_in).astype(cd)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(cd)
    return jnp.einsum("bse,ed->bsd", y, p["w_out"].astype(cd)), S_new, conv_tail


# ---------------------------------------------------------------- hybrid LM
class ZambaLM:
    def __init__(self, cfg: ArchConfig, opts=None):
        from repro.models.transformer import ModelOptions

        self.cfg = cfg
        self.opts = opts or ModelOptions()
        if cfg.n_layers % cfg.attn_every:
            raise ValueError("n_layers must be divisible by attn_every")
        self.n_units = cfg.n_layers // cfg.attn_every
        self.d_in = cfg.ssm_expand * cfg.d_model
        self.ssm_heads = cfg.ssm_heads or (self.d_in // 64)

    def _init_unit(self, key):
        cfg, pdt = self.cfg, self.opts.pdt
        ks = jax.random.split(key, cfg.attn_every)
        return jax.vmap(
            lambda k: init_mamba2(k, cfg.d_model, self.d_in, self.ssm_heads,
                                  cfg.ssm_state, pdt)
        )(ks)

    def init(self, key):
        cfg, pdt = self.cfg, self.opts.pdt
        k_emb, k_units, k_attn, k_mlp, k_head = jax.random.split(key, 5)
        unit_keys = jax.random.split(k_units, self.n_units)
        return {
            "embed": {"tokens": L.dense_init(k_emb, (cfg.padded_vocab, cfg.d_model), dtype=pdt)},
            "units": jax.vmap(self._init_unit)(unit_keys),
            # ONE shared attention block (weights reused at every application)
            "shared": {
                "attn": L.init_attention(
                    k_attn, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                    cfg.resolved_head_dim, dtype=pdt,
                ),
                "attn_norm": L.init_rmsnorm(cfg.d_model, pdt),
                "mlp": L.init_mlp(k_mlp, cfg.d_model, cfg.d_ff, pdt),
                "mlp_norm": L.init_rmsnorm(cfg.d_model, pdt),
            },
            "final_norm": L.init_rmsnorm(cfg.d_model, pdt),
            "lm_head": L.dense_init(k_head, (cfg.d_model, cfg.padded_vocab), dtype=pdt),
        }

    def _shared_attn_fwd(self, sp, x, positions):
        cfg = self.cfg
        h = L.attention_fwd(
            sp["attn"], L.rmsnorm(sp["attn_norm"], x, cfg.norm_eps), positions,
            n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta,
            causal=True, attn_impl=self.opts.attn_impl, chunk=self.opts.attn_chunk,
        )
        x = x + h
        x = x + L.mlp_fwd(sp["mlp"], L.rmsnorm(sp["mlp_norm"], x, cfg.norm_eps))
        return x

    def forward(self, params, batch):
        cfg, cd = self.cfg, self.opts.cdt
        tokens = batch["tokens"]
        x = params["embed"]["tokens"].astype(cd)[tokens]
        x = lshard(x, "batch", "seq", "embed")
        positions = jnp.arange(x.shape[1])[None, :]
        shared = params["shared"]

        def unit_body(x, up):
            def m_body(x, lp):
                y = mamba2_fwd(lp, x, cfg.norm_eps)
                return x + y, None

            fn = m_body
            if self.opts.remat:
                fn = jax.checkpoint(fn, prevent_cse=False)
            x, _ = jax.lax.scan(fn, x, up)
            x = self._shared_attn_fwd(shared, x, positions)
            return lshard(x, "batch", "seq", "embed"), None

        x, _ = jax.lax.scan(unit_body, x, params["units"])
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = _mask_padded_vocab(
            jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(cd)), cfg)
        return lshard(logits, "batch", "seq", "vocab"), jnp.zeros((), jnp.float32)

    def loss(self, params, batch):
        logits, aux = self.forward(params, batch)
        labels = batch["labels"]
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        mask = (labels >= 0).astype(jnp.float32)
        nll = -jnp.take_along_axis(logp, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
        denom = jnp.maximum(mask.sum(), 1.0)
        ce = (nll * mask).sum() / denom
        return ce, {"ce": ce, "aux": aux, "tokens": denom}

    # ----------------------------------------------------------------- serve
    def kv_len(self, max_len: int) -> int:
        w = self.cfg.long_context_window
        return min(max_len, w) if w else max_len

    def init_cache(self, batch: int, max_len: int):
        cfg = self.cfg
        P = self.d_in // self.ssm_heads
        S = jnp.zeros((self.n_units, cfg.attn_every, batch, self.ssm_heads, P, cfg.ssm_state),
                      jnp.float32)
        conv_dim = self.d_in + 2 * cfg.ssm_state
        conv = jnp.zeros(
            (self.n_units, cfg.attn_every, batch, CONV_K - 1, conv_dim), jnp.float32
        )
        kvl = self.kv_len(max_len)
        kv = L.init_kv_cache(batch, kvl, cfg.n_kv_heads, cfg.resolved_head_dim,
                             dtype=self.opts.cdt)
        kv = jax.tree.map(lambda a: jnp.broadcast_to(a, (self.n_units,) + a.shape), kv)
        return {
            "S": S,
            "conv": conv,
            "kv": kv,
            "kv_pos": jnp.full((self.n_units, batch, kvl), -1, jnp.int32),  # ring positions
            "index": jnp.zeros((), jnp.int32),
        }

    def cache_axes(self) -> dict:
        kv = ("units", "batch", "kv_seq", "kv_heads", "head_dim")
        return {
            "S": ("units", "per_unit", "batch", "ssm_heads", None, None),
            "conv": ("units", "per_unit", "batch", None, None),
            "kv": {"k": kv, "v": kv},
            "kv_pos": ("units", "batch", None),
            "index": (),
        }

    def _shared_attn_step(self, sp, x, kvc, kv_pos, index):
        """Ring-buffer single-token shared attention."""
        cfg = self.cfg
        cd = x.dtype
        b = x.shape[0]
        hd, H, K = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads
        kvl = kvc["k"].shape[1]
        slot = index % kvl
        xn = L.rmsnorm(sp["attn_norm"], x, cfg.norm_eps)
        ap = sp["attn"]
        q = jnp.einsum("bsd,dh->bsh", xn, ap["wq"].astype(cd)).reshape(b, 1, H, hd)
        k_new = jnp.einsum("bsd,dh->bsh", xn, ap["wk"].astype(cd)).reshape(b, 1, K, hd)
        v_new = jnp.einsum("bsd,dh->bsh", xn, ap["wv"].astype(cd)).reshape(b, 1, K, hd)
        pos = jnp.full((b, 1), index, jnp.int32)
        q = L.apply_rope(q, pos, cfg.rope_theta)
        k_new = L.apply_rope(k_new, pos, cfg.rope_theta)
        kvc = {
            "k": jax.lax.dynamic_update_slice(kvc["k"], k_new.astype(kvc["k"].dtype), (0, slot, 0, 0)),
            "v": jax.lax.dynamic_update_slice(kvc["v"], v_new.astype(kvc["v"].dtype), (0, slot, 0, 0)),
        }
        kv_pos = jax.lax.dynamic_update_slice(kv_pos, pos, (0, slot))
        k = L._repeat_kv(kvc["k"].astype(cd), H // K)
        v = L._repeat_kv(kvc["v"].astype(cd), H // K)
        valid = (kv_pos >= 0) & (kv_pos <= index)
        mask = valid[:, None, None, :]
        h = L.attention_scores(q, k, v, mask, compute_dtype=cd).reshape(b, 1, H * hd)
        x = x + jnp.einsum("bsh,hd->bsd", h, ap["wo"].astype(cd))
        x = x + L.mlp_fwd(sp["mlp"], L.rmsnorm(sp["mlp_norm"], x, cfg.norm_eps))
        return x, kvc, kv_pos

    def decode_step(self, params, cache, tokens):
        cfg, cd = self.cfg, self.opts.cdt
        x = params["embed"]["tokens"].astype(cd)[tokens]
        index = cache["index"]
        shared = params["shared"]

        def unit_body(x, inp):
            up, S_u, conv_u, kvc, kv_pos = inp

            def m_body(x, inp2):
                lp, S, tail = inp2
                y, S, tail = mamba2_step(lp, x, S, tail, cfg.norm_eps)
                return x + y, (S, tail)

            x, (S_u, conv_u) = jax.lax.scan(m_body, x, (up, S_u, conv_u))
            x, kvc, kv_pos = self._shared_attn_step(shared, x, kvc, kv_pos, index)
            return x, (S_u, conv_u, kvc, kv_pos)

        x, (S, conv, kv, kv_pos) = jax.lax.scan(
            unit_body, x,
            (params["units"], cache["S"], cache["conv"], cache["kv"], cache["kv_pos"]),
        )
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = _mask_padded_vocab(
            jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(cd)), cfg)
        return logits, {"S": S, "conv": conv, "kv": kv, "kv_pos": kv_pos,
                        "index": index + 1}
