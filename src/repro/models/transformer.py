"""Decoder-only transformer LM: dense (granite / minicpm / glm4 / phi4),
MoE (dbrx / qwen3-moe), and VLM (phi-3-vision: backbone + patch-embed stub).

Layers are stacked on a leading axis and applied with ``jax.lax.scan`` (keeps
HLO size O(1) in depth -- essential for the 94-layer qwen3 dry-run) with an
optional per-layer remat policy.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from jax.ad_checkpoint import checkpoint_name

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.parallel.sharding import lshard


@dataclasses.dataclass(frozen=True)
class ModelOptions:
    """Implementation knobs (hillclimbing targets; defaults are the faithful
    baseline configuration)."""

    attn_impl: str = "xla"        # "xla" | "chunked" (O(s) memory)
    attn_chunk: int = 1024
    remat: bool = True            # checkpoint each scanned layer
    remat_policy: str = "full"    # "full" | "save_tp_outputs" (keep the
                                  # post-all-reduce attn/mlp outputs so the
                                  # recompute pass re-does math, not comm)
    scan_layers: bool = True
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    moe_capacity_factor: float = 0.0   # 0 -> use config value

    @property
    def pdt(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdt(self):
        return jnp.dtype(self.compute_dtype)


class DecoderLM:
    """Functional LM; all state in explicit param/cache pytrees."""

    def __init__(self, cfg: ArchConfig, opts: ModelOptions | None = None):
        if cfg.family not in ("dense", "moe", "vlm"):
            raise ValueError(f"DecoderLM does not serve family {cfg.family!r}")
        self.cfg = cfg
        self.opts = opts or ModelOptions()

    # ------------------------------------------------------------------ init
    def _init_layer(self, key):
        cfg, pdt = self.cfg, self.opts.pdt
        k_attn, k_ffn = jax.random.split(key)
        p = {
            "attn": L.init_attention(
                k_attn, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.resolved_head_dim, dtype=pdt,
            ),
            "attn_norm": L.init_rmsnorm(cfg.d_model, pdt),
            "ffn_norm": L.init_rmsnorm(cfg.d_model, pdt),
        }
        if cfg.is_moe:
            p["moe"] = L.init_moe(k_ffn, cfg.d_model, cfg.n_experts, cfg.expert_ff, pdt)
        else:
            p["mlp"] = L.init_mlp(k_ffn, cfg.d_model, cfg.d_ff, pdt)
        return p

    def init(self, key) -> dict:
        cfg, pdt = self.cfg, self.opts.pdt
        k_emb, k_layers, k_head, k_patch = jax.random.split(key, 4)
        layer_keys = jax.random.split(k_layers, cfg.n_layers)
        params = {
            "embed": {"tokens": L.dense_init(k_emb, (cfg.padded_vocab, cfg.d_model), dtype=pdt)},
            "layers": jax.vmap(self._init_layer)(layer_keys),
            "final_norm": L.init_rmsnorm(cfg.d_model, pdt),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = L.dense_init(k_head, (cfg.d_model, cfg.padded_vocab), dtype=pdt)
        if cfg.family == "vlm":
            # modality frontend STUB: a single adapter projecting precomputed
            # patch embeddings into the backbone space.
            params["patch_proj"] = L.dense_init(k_patch, (cfg.d_model, cfg.d_model), dtype=pdt)
        return params

    # --------------------------------------------------------------- forward
    def _layer_fwd(self, lp, x, positions, aux_in):
        cfg = self.cfg
        h = L.attention_fwd(
            lp["attn"], L.rmsnorm(lp["attn_norm"], x, cfg.norm_eps), positions,
            n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta,
            causal=True, attn_impl=self.opts.attn_impl, chunk=self.opts.attn_chunk,
        )
        h = checkpoint_name(h, "attn_out")  # post-TP-all-reduce tensor
        x = x + h
        x = lshard(x, "batch", "seq_sp", "embed")
        normed = L.rmsnorm(lp["ffn_norm"], x, cfg.norm_eps)
        if cfg.is_moe:
            cf = self.opts.moe_capacity_factor or cfg.capacity_factor
            h, aux = L.moe_fwd(lp["moe"], normed, top_k=cfg.top_k,
                               capacity_factor=cf, return_aux=True)
            aux_in = aux_in + aux
        else:
            h = L.mlp_fwd(lp["mlp"], normed)
        h = checkpoint_name(h, "mlp_out")   # post-TP-all-reduce tensor
        x = lshard(x + h, "batch", "seq_sp", "embed")
        return x, aux_in

    def _run_layers(self, params, x, positions):
        aux0 = jnp.zeros((), jnp.float32)

        policy = None
        if self.opts.remat_policy == "save_tp_outputs":
            policy = jax.checkpoint_policies.save_only_these_names(
                "attn_out", "mlp_out")

        def body(carry, lp):
            x, aux = carry
            fn = self._layer_fwd
            if self.opts.remat:
                fn = jax.checkpoint(fn, prevent_cse=False, policy=policy)
            x, aux = fn(lp, x, positions, aux)
            return (x, aux), None

        if self.opts.scan_layers:
            (x, aux), _ = jax.lax.scan(body, (x, aux0), params["layers"])
        else:
            n = self.cfg.n_layers
            aux = aux0
            for i in range(n):
                lp = jax.tree.map(lambda a: a[i], params["layers"])
                (x, aux), _ = body((x, aux), lp)
        return x, aux

    def embed(self, params, tokens):
        cdt = self.opts.cdt
        x = params["embed"]["tokens"].astype(cdt)[tokens]
        return lshard(x, "batch", "seq", "embed")

    def logits(self, params, x):
        cdt = self.opts.cdt
        head = (
            params["embed"]["tokens"].T if self.cfg.tie_embeddings else params["lm_head"]
        ).astype(cdt)
        out = jnp.einsum("bsd,dv->bsv", x, head)
        if self.cfg.padded_vocab != self.cfg.vocab:
            # mask padding entries so the softmax ignores them
            valid = jnp.arange(self.cfg.padded_vocab) < self.cfg.vocab
            out = jnp.where(valid[None, None, :], out, -1e30)
        return lshard(out, "batch", "seq", "vocab")

    def forward(self, params, batch) -> tuple[jax.Array, jax.Array]:
        """batch: {"tokens": (b,s) int32 [, "patches": (b,P,d)]} ->
        (logits (b,s,V), moe aux loss)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        x = self.embed(params, tokens)
        if cfg.family == "vlm":
            patches = batch["patches"].astype(self.opts.cdt)
            prefix = jnp.einsum("bpd,de->bpe", patches, params["patch_proj"].astype(self.opts.cdt))
            x = jnp.concatenate([prefix, x], axis=1)
            x = lshard(x, "batch", "seq", "embed")
        positions = jnp.arange(x.shape[1])[None, :]
        x, aux = self._run_layers(params, x, positions)
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        if cfg.family == "vlm":
            x = x[:, self.cfg.n_patches:, :]  # score only token positions
        return self.logits(params, x), aux

    def loss(self, params, batch) -> tuple[jax.Array, dict]:
        logits, aux = self.forward(params, batch)
        labels = batch["labels"]
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        mask = (labels >= 0).astype(jnp.float32)
        safe = jnp.maximum(labels, 0)
        nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
        denom = jnp.maximum(mask.sum(), 1.0)
        ce = (nll * mask).sum() / denom
        total = ce + 0.01 * aux
        return total, {"ce": ce, "aux": aux, "tokens": denom}

    # ----------------------------------------------------------------- serve
    def init_cache(self, batch: int, max_len: int) -> dict:
        cfg = self.cfg
        kv = L.init_kv_cache(batch, max_len, cfg.n_kv_heads, cfg.resolved_head_dim,
                             dtype=self.opts.cdt)
        return {
            "kv": jax.tree.map(
                lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape), kv
            ),
            "index": jnp.zeros((), jnp.int32),
        }

    def cache_axes(self) -> dict:
        """Logical axis names for every cache leaf (drives sharding)."""
        kv = ("layers", "batch", "kv_seq", "kv_heads", "head_dim")
        return {"kv": {"k": kv, "v": kv}, "index": ()}

    # ---------------------------------------------------------- paged serve
    def init_paged_cache(self, n_pages: int, page_size: int) -> dict:
        """Per-layer paged KV pool (DESIGN.md §7): {"k","v"} of shape
        (n_layers, n_pages, page_size, K, hd).  Page bookkeeping (free list,
        block tables) lives in :class:`repro.serve.kv_cache.PagedKVCache`."""
        cfg = self.cfg
        kv = L.init_paged_kv(n_pages, page_size, cfg.n_kv_heads,
                             cfg.resolved_head_dim, dtype=self.opts.cdt)
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape), kv
        )

    def _paged_layer_stack(self, params, x, attn_fn, pages):
        """Scan the layer stack threading per-layer pages through
        ``attn_fn(layer_params, normed_x, layer_pages) -> (h, new_pages)``."""
        cfg = self.cfg

        def body(x, inp):
            lp, pg = inp
            h, pg = attn_fn(lp, L.rmsnorm(lp["attn_norm"], x, cfg.norm_eps), pg)
            x = x + h
            normed = L.rmsnorm(lp["ffn_norm"], x, cfg.norm_eps)
            if cfg.is_moe:
                cf = self.opts.moe_capacity_factor or cfg.capacity_factor
                h = L.moe_fwd(lp["moe"], normed, top_k=cfg.top_k, capacity_factor=cf)
            else:
                h = L.mlp_fwd(lp["mlp"], normed)
            return x + h, pg

        x, pages = jax.lax.scan(body, x, (params["layers"], pages))
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        return self.logits(params, x), pages

    def decode_step_paged(self, params, pages, block_tables, lengths, tokens,
                          active) -> tuple[jax.Array, dict]:
        """Continuous-batching decode: one token per lane against the paged
        cache.  ``tokens`` (b, 1); ``block_tables`` (b, max_blocks);
        ``lengths``/``active`` (b,).  Returns (logits (b, 1, V), new pages)."""
        cfg = self.cfg
        x = self.embed(params, tokens)
        attn = lambda lp, normed, pg: L.attention_decode_paged(
            lp["attn"], normed, pg, block_tables, lengths, active,
            n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta,
        )
        return self._paged_layer_stack(params, x, attn, pages)

    def prefill_paged(self, params, pages, block_table, length, tokens
                      ) -> tuple[jax.Array, dict]:
        """Prefill one sequence (tokens (1, S) padded, true length
        ``length``), scattering its KV into pages.  Returns (logits (1, S, V),
        new pages); the caller samples from position ``length - 1``."""
        cfg = self.cfg
        x = self.embed(params, tokens)
        attn = lambda lp, normed, pg: L.attention_prefill_paged(
            lp["attn"], normed, pg, block_table, length,
            n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta,
        )
        return self._paged_layer_stack(params, x, attn, pages)

    def decode_step(self, params, cache, tokens) -> tuple[jax.Array, dict]:
        """One-token decode: tokens (b, 1) -> (logits (b, 1, V), new cache)."""
        cfg = self.cfg
        x = self.embed(params, tokens)
        index = cache["index"]

        def body(x, inp):
            lp, kvc = inp
            h, kvc = L.attention_decode(
                lp["attn"], L.rmsnorm(lp["attn_norm"], x, cfg.norm_eps), kvc, index,
                n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta,
            )
            x = x + h
            normed = L.rmsnorm(lp["ffn_norm"], x, cfg.norm_eps)
            if cfg.is_moe:
                cf = self.opts.moe_capacity_factor or cfg.capacity_factor
                h = L.moe_fwd(lp["moe"], normed, top_k=cfg.top_k, capacity_factor=cf)
            else:
                h = L.mlp_fwd(lp["mlp"], normed)
            return x + h, kvc

        x, kv = jax.lax.scan(body, x, (params["layers"], cache["kv"]))
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        return self.logits(params, x), {"kv": kv, "index": index + 1}
