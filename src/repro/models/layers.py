"""Foundational layers: RMSNorm, RoPE, GQA attention (train / prefill /
decode-with-KV-cache), SwiGLU MLP, MoE FFN with top-k routing.

Pure-JAX functional style: every layer is an ``init_*`` returning a param
pytree + an ``apply`` function.  Activations carry logical sharding
annotations (:func:`repro.parallel.sharding.lshard`) so the same code runs
single-device (no-op) and under the production meshes.

Dtype policy (mixed precision): parameters live in ``param_dtype`` (fp32 by
default), compute runs in ``compute_dtype`` (bf16), softmax/normalizers and
the loss in fp32.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.parallel.sharding import active_mesh, lshard


@dataclasses.dataclass(frozen=True)
class DTypes:
    param: jnp.dtype = jnp.float32
    compute: jnp.dtype = jnp.bfloat16


DEFAULT_DTYPES = DTypes()


def dense_init(key, shape, in_axis: int = 0, dtype=jnp.float32):
    """Truncated-normal fan-in init (matches common LLM pretrain setups)."""
    fan_in = shape[in_axis]
    std = 1.0 / np.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


# ------------------------------------------------------------------- RMSNorm
def init_rmsnorm(d: int, dtype=jnp.float32):
    return {"norm_scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * params["norm_scale"].astype(jnp.float32)
    return out.astype(dt)


# ---------------------------------------------------------------------- RoPE
def rope_angles(positions, head_dim: int, theta: float):
    """(..., hd/2) rotation angles for integer positions."""
    freqs = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))
    return positions[..., None].astype(jnp.float32) * freqs  # (..., hd/2)


def apply_rope(x, positions, theta: float):
    """x: (b, s, h, hd); positions: (b, s) or (s,)."""
    hd = x.shape[-1]
    ang = rope_angles(positions, hd, theta)  # (b, s, hd/2) or (s, hd/2)
    if ang.ndim == 2:
        ang = ang[None, :, :]
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., ::2], x[..., 1::2]
    xr = jnp.stack([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return xr.reshape(x.shape).astype(x.dtype)


# ----------------------------------------------------------------- attention
def init_attention(key, d_model: int, n_heads: int, n_kv_heads: int, head_dim: int,
                   dtype=jnp.float32):
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": dense_init(kq, (d_model, n_heads * head_dim), dtype=dtype),
        "wk": dense_init(kk, (d_model, n_kv_heads * head_dim), dtype=dtype),
        "wv": dense_init(kv, (d_model, n_kv_heads * head_dim), dtype=dtype),
        "wo": dense_init(ko, (n_heads * head_dim, d_model), dtype=dtype),
    }


def _split_heads(x, n_heads, head_dim):
    b, s, _ = x.shape
    return x.reshape(b, s, n_heads, head_dim)


def _repeat_kv(k, n_rep: int):
    if n_rep == 1:
        return k
    b, s, kv, hd = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, kv, n_rep, hd)).reshape(
        b, s, kv * n_rep, hd
    )


def attention_scores(q, k, v, mask, compute_dtype=jnp.bfloat16):
    """q: (b, sq, H, hd), k/v: (b, sk, H, hd); mask broadcastable to
    (b, H, sq, sk) (True = attend).  fp32 softmax."""
    hd = q.shape[-1]
    scores = jnp.einsum(
        "bqhd,bkhd->bhqk", q, k, preferred_element_type=jnp.float32
    ) / np.sqrt(hd)
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
    out = jnp.einsum(
        "bhqk,bkhd->bqhd", probs.astype(compute_dtype), v.astype(compute_dtype),
        preferred_element_type=jnp.float32,
    )
    return out.astype(compute_dtype)


def attention_fwd(
    params,
    x,
    positions,
    *,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    rope_theta: float = 1e4,
    causal: bool = True,
    kv_override=None,          # cross-attention: (k_src, v_src) already projected
    attn_impl: str = "xla",    # "xla" | "chunked" (sub-quadratic memory)
    chunk: int = 1024,
    use_rope: bool = True,     # False: absolute-position models (whisper)
):
    """Full-sequence attention (training / prefill)."""
    b, s, _ = x.shape
    cd = x.dtype
    q = _split_heads(jnp.einsum("bsd,dh->bsh", x, params["wq"].astype(cd)), n_heads, head_dim)
    if kv_override is None:
        k = _split_heads(jnp.einsum("bsd,dh->bsh", x, params["wk"].astype(cd)), n_kv_heads, head_dim)
        v = _split_heads(jnp.einsum("bsd,dh->bsh", x, params["wv"].astype(cd)), n_kv_heads, head_dim)
        if use_rope:
            q = apply_rope(q, positions, rope_theta)
            k = apply_rope(k, positions, rope_theta)
    else:
        k, v = kv_override
    q = lshard(q, "batch", "seq", "heads", "head_dim")
    k = lshard(k, "batch", None, "kv_heads", "head_dim")
    v = lshard(v, "batch", None, "kv_heads", "head_dim")
    k = _repeat_kv(k, n_heads // k.shape[2])
    v = _repeat_kv(v, n_heads // v.shape[2])

    sk = k.shape[1]
    if attn_impl == "chunked" and s > chunk:
        out = _chunked_attention(q, k, v, causal, chunk)
    else:
        if causal:
            mask = jnp.tril(jnp.ones((s, sk), dtype=bool), k=sk - s)[None, None]
        else:
            mask = jnp.ones((1, 1, s, sk), dtype=bool)
        out = attention_scores(q, k, v, mask, compute_dtype=cd)
    out = lshard(out, "batch", "seq", "heads", "head_dim")
    out = out.reshape(b, s, n_heads * head_dim)
    return jnp.einsum("bsh,hd->bsd", out, params["wo"].astype(cd))


def _chunked_attention(q, k, v, causal: bool, chunk: int):
    """Flash-style O(s) memory attention: scan over KV chunks with an online
    softmax; the XLA counterpart of the Pallas kernel (kernels/flash_attention)."""
    b, s, h, hd = q.shape
    sk = k.shape[1]
    n_chunks = (sk + chunk - 1) // chunk
    pad = n_chunks * chunk - sk
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = kp.reshape(b, n_chunks, chunk, h, hd)
    vc = vp.reshape(b, n_chunks, chunk, h, hd)
    q32 = q.astype(jnp.float32) / np.sqrt(hd)
    qpos = jnp.arange(s) + (sk - s)  # align to causal offset

    def body(carry, inp):
        m, l, acc = carry
        kci, vci, ci = inp
        kpos = ci * chunk + jnp.arange(chunk)
        scores = jnp.einsum("bqhd,bkhd->bhqk", q32, kci.astype(jnp.float32))
        valid = (kpos < sk)[None, None, None, :]
        if causal:
            valid = valid & (qpos[None, None, :, None] >= kpos[None, None, None, :])
        scores = jnp.where(valid, scores, -1e30)
        m_new = jnp.maximum(m, scores.max(axis=-1))
        p = jnp.exp(scores - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p, vci.astype(jnp.float32)
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, h, s), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, h, s), jnp.float32)
    acc0 = jnp.zeros((b, h, s, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body,
        (m0, l0, acc0),
        (kc.swapaxes(0, 1), vc.swapaxes(0, 1), jnp.arange(n_chunks)),
    )
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.swapaxes(1, 2).astype(q.dtype)  # (b, s, h, hd)


# --------------------------------------------------------------- KV caching
def init_kv_cache(batch: int, max_len: int, n_kv_heads: int, head_dim: int,
                  dtype=jnp.bfloat16):
    return {
        "k": jnp.zeros((batch, max_len, n_kv_heads, head_dim), dtype),
        "v": jnp.zeros((batch, max_len, n_kv_heads, head_dim), dtype),
    }


def attention_decode(
    params,
    x,                 # (b, 1, d)
    cache,             # {"k","v"} (b, L, K, hd)
    index,             # scalar int32: write position (= current length)
    *,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    rope_theta: float = 1e4,
    update_cache: bool = True,
    window: int = 0,   # sliding window size (0 = full)
    use_rope: bool = True,
):
    """Single-token decode with KV cache; O(L) compute, O(1) state growth."""
    b = x.shape[0]
    cd = x.dtype
    q = _split_heads(jnp.einsum("bsd,dh->bsh", x, params["wq"].astype(cd)), n_heads, head_dim)
    pos = jnp.full((b, 1), index, dtype=jnp.int32)
    if use_rope:
        q = apply_rope(q, pos, rope_theta)
    if update_cache:
        k_new = _split_heads(jnp.einsum("bsd,dh->bsh", x, params["wk"].astype(cd)), n_kv_heads, head_dim)
        v_new = _split_heads(jnp.einsum("bsd,dh->bsh", x, params["wv"].astype(cd)), n_kv_heads, head_dim)
        if use_rope:
            k_new = apply_rope(k_new, pos, rope_theta)
        cache = {
            "k": jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype), (0, index, 0, 0)),
            "v": jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype), (0, index, 0, 0)),
        }
    k, v = cache["k"], cache["v"]
    L = k.shape[1]
    kpos = jnp.arange(L)
    valid = kpos <= index
    if window:
        valid = valid & (kpos > index - window)

    # flash-decoding path: when GQA heads do not divide the TP axis, the KV
    # cache is sharded on the *sequence* dim; computing scores against a
    # heads-sharded q would force XLA to all-gather the whole cache (GBs per
    # token).  Instead keep scores seq-sharded (partial attention per shard)
    # -- the softmax/normalizer all-reduces and the (b,1,H,hd) output
    # reduction move only KBs.
    mesh = active_mesh()
    seq_flash = (
        mesh is not None
        and "model" in mesh.axis_names
        and n_kv_heads % mesh.shape["model"] != 0
        and L % mesh.shape["model"] == 0
    )
    if seq_flash:
        from repro.parallel.sharding import data_axis_names, pshard

        da = data_axis_names()
        k = pshard(k, da, "model", None, None)
        v = pshard(v, da, "model", None, None)
        k = _repeat_kv(k.astype(cd), n_heads // n_kv_heads)
        v = _repeat_kv(v.astype(cd), n_heads // n_kv_heads)
        q_r = pshard(q, da, None, None, None)  # replicate q heads
        scores = jnp.einsum(
            "bqhd,bkhd->bhqk", q_r, k, preferred_element_type=jnp.float32
        ) / np.sqrt(head_dim)
        scores = jnp.where(valid[None, None, None, :], scores, -1e30)
        scores = pshard(scores, da, None, None, "model")  # seq-sharded
        probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
        out = jnp.einsum(
            "bhqk,bkhd->bqhd", probs.astype(cd), v, preferred_element_type=jnp.float32
        ).astype(cd)
        out = pshard(out, da, None, None, None)
    else:
        k = lshard(k, "batch", None, "kv_heads", "head_dim")
        v = lshard(v, "batch", None, "kv_heads", "head_dim")
        k = _repeat_kv(k.astype(cd), n_heads // n_kv_heads)
        v = _repeat_kv(v.astype(cd), n_heads // n_kv_heads)
        mask = valid[None, None, None, :]
        out = attention_scores(q, k, v, mask, compute_dtype=cd)  # (b,1,H,hd)
    out = out.reshape(b, 1, n_heads * head_dim)
    return jnp.einsum("bsh,hd->bsd", out, params["wo"].astype(cd)), cache


# ------------------------------------------------------- paged KV attention
#
# Serving variant of the cache (DESIGN.md §7): instead of one dense
# (batch, max_len, ...) buffer per layer, KV lives in fixed-size *pages*
# shared by all sequences -- {"k","v"}: (n_pages, page_size, K, hd) -- and
# each sequence owns an ordered *block table* of page ids.  Logical position
# ``p`` of a sequence maps to physical slot ``table[p // ps] * ps + p % ps``.
# The allocator/bookkeeping lives in :mod:`repro.serve.kv_cache`; these
# functions are the pure-JAX compute: scatter new KV into pages, gather a
# sequence's pages back into a contiguous view, and attend with the same
# fp32-softmax math as the dense path (so paged and dense decode are
# token-identical -- the engine equivalence tests rely on it).


def init_paged_kv(n_pages: int, page_size: int, n_kv_heads: int, head_dim: int,
                  dtype=jnp.bfloat16):
    return {
        "k": jnp.zeros((n_pages, page_size, n_kv_heads, head_dim), dtype),
        "v": jnp.zeros((n_pages, page_size, n_kv_heads, head_dim), dtype),
    }


def _paged_scatter(pages_flat, values, slots):
    """Write ``values`` (n, K, hd) at flat slots (n,); out-of-range slots
    (inactive lanes / padding) are dropped, not clamped."""
    return pages_flat.at[slots].set(values.astype(pages_flat.dtype), mode="drop")


def attention_decode_paged(
    params,
    x,                 # (b, 1, d) -- one new token per lane
    pages,             # {"k","v"}: (n_pages, page_size, K, hd)
    block_table,       # (b, max_blocks) int32 page ids, -1 = unallocated
    lengths,           # (b,) int32: tokens already cached per lane
    active,            # (b,) bool: lane holds a live sequence
    *,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    rope_theta: float = 1e4,
    use_rope: bool = True,
):
    """Single-token decode against a paged KV cache.

    Unlike :func:`attention_decode` (one scalar write index for the whole
    batch) every lane carries its own length, which is what lets the engine
    admit requests mid-flight: lane i writes at logical position
    ``lengths[i]`` and attends positions ``<= lengths[i]``.  Inactive lanes
    are masked out of the scatter entirely (their block tables are empty).
    """
    b = x.shape[0]
    cd = x.dtype
    n_pages, ps = pages["k"].shape[:2]
    max_blocks = block_table.shape[1]
    q = _split_heads(jnp.einsum("bsd,dh->bsh", x, params["wq"].astype(cd)), n_heads, head_dim)
    k_new = _split_heads(jnp.einsum("bsd,dh->bsh", x, params["wk"].astype(cd)), n_kv_heads, head_dim)
    v_new = _split_heads(jnp.einsum("bsd,dh->bsh", x, params["wv"].astype(cd)), n_kv_heads, head_dim)
    pos = lengths[:, None]  # (b, 1)
    if use_rope:
        q = apply_rope(q, pos, rope_theta)
        k_new = apply_rope(k_new, pos, rope_theta)

    write_block = jnp.take_along_axis(
        block_table, (lengths // ps)[:, None] % max_blocks, axis=1
    )[:, 0]
    slots = write_block * ps + lengths % ps
    slots = jnp.where(active & (write_block >= 0), slots, n_pages * ps)  # drop
    flat_k = _paged_scatter(pages["k"].reshape(n_pages * ps, n_kv_heads, head_dim), k_new[:, 0], slots)
    flat_v = _paged_scatter(pages["v"].reshape(n_pages * ps, n_kv_heads, head_dim), v_new[:, 0], slots)

    # gather each lane's pages into a contiguous (L = max_blocks*ps) view
    safe_table = jnp.where(block_table >= 0, block_table, 0)
    idx = (safe_table[:, :, None] * ps + jnp.arange(ps)[None, None, :]).reshape(b, -1)
    k = flat_k[idx]  # (b, L, K, hd)
    v = flat_v[idx]
    kpos = jnp.arange(max_blocks * ps)
    valid = (kpos[None, :] <= lengths[:, None]) & jnp.repeat(block_table >= 0, ps, axis=1)
    k = _repeat_kv(k.astype(cd), n_heads // n_kv_heads)
    v = _repeat_kv(v.astype(cd), n_heads // n_kv_heads)
    out = attention_scores(q, k, v, valid[:, None, None, :], compute_dtype=cd)
    out = out.reshape(b, 1, n_heads * head_dim)
    proj = jnp.einsum("bsh,hd->bsd", out, params["wo"].astype(cd))
    new_pages = {
        "k": flat_k.reshape(n_pages, ps, n_kv_heads, head_dim),
        "v": flat_v.reshape(n_pages, ps, n_kv_heads, head_dim),
    }
    return proj, new_pages


def attention_prefill_paged(
    params,
    x,                 # (1, S, d) -- padded prompt for one sequence
    pages,             # {"k","v"}: (n_pages, page_size, K, hd)
    block_table,       # (max_blocks,) int32 page ids, -1 = unallocated
    length,            # scalar int32: true prompt length (<= S)
    *,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    rope_theta: float = 1e4,
    use_rope: bool = True,
):
    """Full-prompt prefill for one sequence, scattering its KV into pages.

    The prompt is padded to a bucketed S (bounding jit retraces); causal
    masking means padding positions never influence positions < ``length``,
    and their KV is dropped from the scatter, so pages hold exactly the
    ``length`` real tokens afterwards.
    """
    _, s, _ = x.shape
    cd = x.dtype
    n_pages, ps = pages["k"].shape[:2]
    positions = jnp.arange(s)[None, :]
    q = _split_heads(jnp.einsum("bsd,dh->bsh", x, params["wq"].astype(cd)), n_heads, head_dim)
    k = _split_heads(jnp.einsum("bsd,dh->bsh", x, params["wk"].astype(cd)), n_kv_heads, head_dim)
    v = _split_heads(jnp.einsum("bsd,dh->bsh", x, params["wv"].astype(cd)), n_kv_heads, head_dim)
    if use_rope:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)

    pos = jnp.arange(s)
    blocks = block_table[(pos // ps) % block_table.shape[0]]
    slots = blocks * ps + pos % ps
    slots = jnp.where((pos < length) & (blocks >= 0), slots, n_pages * ps)
    flat_k = _paged_scatter(pages["k"].reshape(n_pages * ps, n_kv_heads, head_dim), k[0], slots)
    flat_v = _paged_scatter(pages["v"].reshape(n_pages * ps, n_kv_heads, head_dim), v[0], slots)

    kr = _repeat_kv(k, n_heads // n_kv_heads)
    vr = _repeat_kv(v, n_heads // n_kv_heads)
    mask = jnp.tril(jnp.ones((s, s), dtype=bool))[None, None]
    out = attention_scores(q, kr, vr, mask, compute_dtype=cd)
    out = out.reshape(1, s, n_heads * head_dim)
    proj = jnp.einsum("bsh,hd->bsd", out, params["wo"].astype(cd))
    new_pages = {
        "k": flat_k.reshape(n_pages, ps, n_kv_heads, head_dim),
        "v": flat_v.reshape(n_pages, ps, n_kv_heads, head_dim),
    }
    return proj, new_pages


# -------------------------------------------------------------------- SwiGLU
def init_mlp(key, d_model: int, d_ff: int, dtype=jnp.float32):
    kg, ki, ko = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(kg, (d_model, d_ff), dtype=dtype),
        "w_in": dense_init(ki, (d_model, d_ff), dtype=dtype),
        "w_out": dense_init(ko, (d_ff, d_model), dtype=dtype),
    }


def mlp_fwd(params, x):
    cd = x.dtype
    g = jnp.einsum("bsd,df->bsf", x, params["w_gate"].astype(cd))
    h = jnp.einsum("bsd,df->bsf", x, params["w_in"].astype(cd))
    g = lshard(g, "batch", "seq", "ffn")
    act = jax.nn.silu(g.astype(jnp.float32)).astype(cd) * h
    return jnp.einsum("bsf,fd->bsd", act, params["w_out"].astype(cd))


# ----------------------------------------------------------------------- MoE
def init_moe(key, d_model: int, n_experts: int, d_expert: int, dtype=jnp.float32):
    kr, kg, ki, ko = jax.random.split(key, 4)
    return {
        "router": dense_init(kr, (d_model, n_experts), dtype=jnp.float32),
        "w_gate": dense_init(kg, (n_experts, d_model, d_expert), in_axis=1, dtype=dtype),
        "w_in": dense_init(ki, (n_experts, d_model, d_expert), in_axis=1, dtype=dtype),
        "w_out": dense_init(ko, (n_experts, d_expert, d_model), in_axis=1, dtype=dtype),
    }


def moe_fwd(params, x, *, top_k: int, capacity_factor: float = 1.25,
            group_size: int = 512, return_aux: bool = False):
    """Token-choice top-k MoE with *grouped* capacity-based dense dispatch
    (the GSPMD-canonical formulation).

    Tokens are blocked into groups of ``group_size``; capacity and the
    one-hot dispatch/combine tensors are per-group, so their footprint is
    O(groups * group_size * E * capacity) instead of O(total_tokens^2 / E).
    Under pjit with experts sharded over `model` and groups over the data
    axes, XLA lowers dispatch/combine einsums to all-to-all -- the EP
    traffic modeled by ``v_e`` in the comm matrix.
    """
    b, s, d = x.shape
    E = params["router"].shape[-1]
    n_tokens = b * s
    gs = min(group_size, n_tokens)
    while n_tokens % gs:
        gs //= 2  # shapes in this framework are powers of two
    G = n_tokens // gs
    xt = x.reshape(G, gs, d)
    xt = lshard(xt, "batch", None, "embed")

    logits = jnp.einsum("gtd,de->gte", xt.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)          # (G, gs, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    capacity = max(4, int(np.ceil(top_k * gs / E * capacity_factor)))

    # position of each (token, k) within its expert's per-group queue
    onehot = jax.nn.one_hot(expert_idx, E, dtype=jnp.int32)      # (G, gs, k, E)
    flat = onehot.reshape(G, gs * top_k, E)
    pos_in_expert = (jnp.cumsum(flat, axis=1) - flat).reshape(G, gs, top_k, E)
    pos = jnp.sum(pos_in_expert * onehot, axis=-1)               # (G, gs, k)
    keep = pos < capacity

    pos_oh = jax.nn.one_hot(pos, capacity, dtype=xt.dtype) * keep[..., None].astype(xt.dtype)
    sel = onehot.astype(xt.dtype)[..., None] * pos_oh[:, :, :, None, :]  # (G,gs,k,E,C)
    dispatch = sel.sum(axis=2)                                    # (G, gs, E, C)
    combine = jnp.einsum("gtk,gtkec->gtec", gate_vals.astype(xt.dtype), sel)

    xe = jnp.einsum("gtd,gtec->gecd", xt, dispatch)               # (G, E, C, d)
    xe = lshard(xe, "batch", "experts", None, "embed")
    g = jnp.einsum("gecd,edf->gecf", xe, params["w_gate"].astype(xt.dtype))
    h = jnp.einsum("gecd,edf->gecf", xe, params["w_in"].astype(xt.dtype))
    act = jax.nn.silu(g.astype(jnp.float32)).astype(xt.dtype) * h
    ye = jnp.einsum("gecf,efd->gecd", act, params["w_out"].astype(xt.dtype))
    ye = lshard(ye, "batch", "experts", None, "embed")
    out = jnp.einsum("gecd,gtec->gtd", ye, combine).reshape(b, s, d)

    if return_aux:
        # load-balancing auxiliary loss (Switch-style), over all tokens
        me = probs.reshape(n_tokens, E).mean(axis=0)
        ce = onehot.reshape(n_tokens, top_k, E).sum(axis=1).mean(axis=0).astype(jnp.float32)
        aux = E * jnp.sum(me * ce)
        return out, aux
    return out
