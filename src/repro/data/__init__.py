from repro.data.pipeline import MarkovLM, Prefetcher, SyntheticDataset

__all__ = ["MarkovLM", "Prefetcher", "SyntheticDataset"]
