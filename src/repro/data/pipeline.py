"""Deterministic synthetic LM data pipeline with background prefetch.

Offline container => no real corpus; the stream is a seeded sparse Markov
chain over the vocabulary, which has low intrinsic entropy so short training
runs show a *decreasing* loss (quickstart/e2e examples assert this).  Every
batch is a pure function of (seed, step): restart-safe by construction --
resuming from step k reproduces the exact token stream, which is what makes
checkpoint-restart bit-reproducible.
"""

from __future__ import annotations

import queue
import threading

import numpy as np


class MarkovLM:
    """Order-1 Markov chain with ``branching`` successors per token."""

    def __init__(self, vocab: int, seed: int = 0, branching: int = 4):
        rng = np.random.default_rng(seed)
        self.vocab = vocab
        self.branching = branching
        # successor table (vocab, branching) + skewed transition probs
        self.succ = rng.integers(0, vocab, size=(vocab, branching))
        p = rng.dirichlet(np.full(branching, 0.35), size=vocab)
        self.probs = p

    def sample(self, rng: np.random.Generator, batch: int, seq: int) -> np.ndarray:
        out = np.empty((batch, seq + 1), np.int64)
        out[:, 0] = rng.integers(0, self.vocab, size=batch)
        for t in range(seq):
            cur = out[:, t]
            choice = np.array(
                [rng.choice(self.branching, p=self.probs[c]) for c in cur]
            )
            out[:, t + 1] = self.succ[cur, choice]
        return out


class SyntheticDataset:
    """Deterministic ``batch(step)`` -> {"tokens", "labels"} (next-token)."""

    def __init__(self, vocab: int, seq_len: int, global_batch: int, seed: int = 0,
                 extra_specs: dict | None = None):
        self.lm = MarkovLM(vocab, seed)
        self.vocab = vocab
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.seed = seed
        self.extra_specs = extra_specs or {}

    def batch(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        toks = self.lm.sample(rng, self.global_batch, self.seq_len)
        out = {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }
        for name, (shape, dtype) in self.extra_specs.items():
            out[name] = (rng.standard_normal(shape) * 0.1).astype(dtype)
        return out


class Prefetcher:
    """Background-thread double buffering: hides host-side batch generation
    behind device compute (the standard input-pipeline overlap trick)."""

    def __init__(self, dataset: SyntheticDataset, start_step: int = 0, depth: int = 2):
        self.dataset = dataset
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            batch = self.dataset.batch(step)
            while not self._stop.is_set():
                try:
                    self.q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def next(self) -> tuple[int, dict]:
        return self.q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)
