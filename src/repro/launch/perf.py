import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimbing driver: run named experiment variants of one
(arch x shape) cell on the single-pod mesh, re-deriving the roofline per
variant, and append hypothesis->before->after records to
reports/perf_<arch>_<shape>.json.

Usage:
    python -m repro.launch.perf --arch granite-8b --shape train_4k \
        --variant baseline --variant no_fsdp ...
"""

import argparse
import json
import pathlib

from repro.launch.dryrun import run_cell

#: named experiment variants: (opt_overrides, rule_overrides, microbatches)
VARIANTS: dict[str, dict] = {
    "baseline": {},
    # --- collective-bound candidates -------------------------------------
    "no_fsdp": {"rule_overrides": {"fsdp": ()}},          # replicate weights
    "mb1": {"microbatches": 1},                           # one regather/step
    "mb2": {"microbatches": 2},
    "mb4": {"microbatches": 4},
    # --- memory-bound candidates ------------------------------------------
    "no_remat": {"opt_overrides": {"remat": False}},
    "seq_parallel": {"rule_overrides": {"seq_sp": ("model",)}},
    "remat_save_tp": {"opt_overrides": {"remat_policy": "save_tp_outputs"}},
    "sp_remat_tp": {"rule_overrides": {"seq_sp": ("model",)},
                    "opt_overrides": {"remat_policy": "save_tp_outputs"}},
    "attn_chunk_512": {"opt_overrides": {"attn_impl": "chunked", "attn_chunk": 512}},
    "attn_chunk_2048": {"opt_overrides": {"attn_impl": "chunked", "attn_chunk": 2048}},
    "attn_chunk_4096": {"opt_overrides": {"attn_impl": "chunked", "attn_chunk": 4096}},
    "attn_xla": {"opt_overrides": {"attn_impl": "xla"}},
    # --- compute/efficiency -----------------------------------------------
    "moe_cap_1.0": {"opt_overrides": {"moe_capacity_factor": 1.0}},
    "moe_cap_2.0": {"opt_overrides": {"moe_capacity_factor": 2.0}},
    # combinations get added per-cell during the hillclimb
    "no_fsdp_mb1": {"rule_overrides": {"fsdp": ()}, "microbatches": 1},
    # full ZeRO-3 data parallelism over ALL chips, no tensor parallelism:
    # eliminates the per-layer TP activation all-reduces entirely; weights
    # stream via all-gather instead (16 GB/pass for an 8B model)
    "fsdp_only": {"rule_overrides": {
        "heads": (), "kv_heads": (), "ffn": (), "vocab": (),
        "fsdp": ("data", "model"), "zero": ("data", "model"),
        "batch": ("data", "model")}, "microbatches": 1},
    "fsdp_only_remat_tp": {"opt_overrides": {"remat_policy": "save_tp_outputs"},
                           "rule_overrides": {
        "heads": (), "kv_heads": (), "ffn": (), "vocab": (),
        "fsdp": ("data", "model"), "zero": ("data", "model"),
        "batch": ("data", "model")}, "microbatches": 1},
    "fsdp_only_mb2": {"rule_overrides": {
        "heads": (), "kv_heads": (), "ffn": (), "vocab": (),
        "fsdp": ("data", "model"), "zero": ("data", "model"),
        "batch": ("data", "model")}, "microbatches": 2},
    "mb1_seqpar": {"microbatches": 1, "rule_overrides": {"seq_sp": ("model",)}},
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", action="append", default=None,
                    choices=sorted(VARIANTS), dest="variants")
    ap.add_argument("--out", default="reports")
    args = ap.parse_args()

    variants = args.variants or ["baseline"]
    out = pathlib.Path(args.out) / f"perf_{args.arch}_{args.shape}.json"
    records = []
    if out.exists():
        records = json.loads(out.read_text())
    done = {r["variant"] for r in records}

    for name in variants:
        if name in done:
            print(f"{name}: cached")
            continue
        kw = VARIANTS[name]
        try:
            rec = run_cell(args.arch, args.shape, multi_pod=False,
                           with_analysis=True, analysis_true_microbatches=True,
                           **kw)
            rec["variant"] = name
        except Exception as e:  # noqa: BLE001
            rec = {"variant": name, "status": f"FAILED: {e}"}
            print(f"{name}: FAILED {e}")
        records.append(rec)
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(json.dumps(records, indent=1))
    # summary table
    print(f"\n{'variant':18s} {'dominant':10s} {'compute_s':>10s} {'memory_s':>10s} "
          f"{'coll_s':>10s} {'bound_s':>10s} {'peakGiB':>8s}")
    for r in records:
        if r.get("status") != "ok" or "roofline" not in r:
            continue
        rl = r["roofline"]
        bound = max(rl["compute_s"], rl["memory_s"], rl["collective_s"])
        peak = (r["memory"]["peak_bytes_per_device"] or 0) / 2**30
        print(f"{r['variant']:18s} {rl['dominant']:10s} {rl['compute_s']:10.3e} "
              f"{rl['memory_s']:10.3e} {rl['collective_s']:10.3e} "
              f"{bound:10.3e} {peak:8.2f}")


if __name__ == "__main__":
    main()
