"""Roofline analysis from compiled dry-run artifacts (deliverable g).

Three terms per (arch x shape x mesh), all in seconds:

    compute    = HLO_FLOPs / (chips * 197 TFLOP/s)
    memory     = HLO_bytes / (chips * 819 GB/s)
    collective = collective_bytes / (chips * links * 50 GB/s)

``cost_analysis()`` provides HLO FLOPs / bytes; collective bytes are parsed
from the compiled HLO text by summing the result-buffer sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
(documented convention: result size ~ bytes landing on each participant for
ring algorithms).  MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) gives
the useful-compute ratio that catches remat / dispatch waste.
"""

from __future__ import annotations

import dataclasses
import re

import numpy as np

TPU_PEAK_FLOPS = 197e12    # bf16, per chip
TPU_HBM_BW = 819e9         # bytes/s per chip
TPU_ICI_LINK_BW = 50e9     # bytes/s per link
ICI_LINKS_PER_CHIP = 4     # v5e 2D torus: 4 links

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
    "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

#: result-defining HLO line, e.g. ``%ag = bf16[2,4096,128]{2,1,0} all-gather(...)``
_OP_RE = re.compile(
    r"=\s*(?:\([^)]*\)\s*)?(\w+)\[([\d,]*)\][^a-zA-Z]*\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
)
_TUPLE_RE = re.compile(
    r"=\s*\(([^)]*)\)\s*(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collective_bytes(hlo_text: str) -> dict:
    """Sum result-buffer bytes per collective kind over the whole module.

    Async pairs are counted at the ``-done`` op (whose result is the full
    gathered/reduced buffer); ``-start`` lines are skipped so nothing is
    double-counted.  NOTE: ops inside ``while`` bodies are counted once --
    callers must pass an HLO with unrolled layer loops (the dry-run's
    analysis compile) for trip-count-true totals.
    """
    out: dict[str, int] = {}
    for line in hlo_text.splitlines():
        if "-start" in line:
            continue  # async start: counted at the matching -done
        m = _OP_RE.search(line)
        kind = None
        total = 0
        if m:
            kind = m.group(3)
            total = _shape_bytes(m.group(1), m.group(2))
        else:
            mt = _TUPLE_RE.search(line)
            if mt:
                kind = mt.group(2)
                total = sum(
                    _shape_bytes(d, s) for d, s in _SHAPE_RE.findall(mt.group(1))
                )
        if kind is None:
            continue
        out[kind] = out.get(kind, 0) + total
    return out


def inner_scan_flops(cfg, shape_spec) -> float:
    """Closed-form GLOBAL flops of recurrences that remain inside ``while``
    bodies even in the unrolled analysis compile (xLSTM time scans, Mamba2
    chunk scans) and are therefore invisible to ``cost_analysis``.

    Forward-only; the caller multiplies by 3 for train (bwd ~ 2x fwd).
    """
    if cfg.family not in ("ssm", "hybrid") or shape_spec.kind == "decode":
        return 0.0
    b = shape_spec.global_batch
    s = shape_spec.seq_len
    if cfg.family == "ssm":
        d_in = cfg.ssm_expand * cfg.d_model
        H = cfg.n_heads
        dh = d_in // H
        n_units = cfg.n_layers // cfg.slstm_every
        n_m = n_units * (cfg.slstm_every - 1)
        n_s = n_units
        mlstm = 6.0 * b * s * n_m * H * dh * dh      # C update + C.q per step
        slstm = 8.0 * b * s * n_s * H * dh * dh      # recurrent gate matmuls
        return mlstm + slstm
    # hybrid (mamba2 chunk scan, chunk=128)
    d_in = cfg.ssm_expand * cfg.d_model
    H = cfg.ssm_heads or (d_in // 64)
    P = d_in // H
    N = cfg.ssm_state
    cs = 128
    n_chunks = max(1, s // cs)
    per_chunk = 2.0 * cs * cs * (N + P) + 4.0 * cs * P * N
    return float(b * H * n_chunks * per_chunk * cfg.n_layers)


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    collectives: dict
    model_flops: float
    analytic_bytes: float = 0.0  # modeled true HBM traffic (global)
    compute_s: float = 0.0
    memory_s: float = 0.0
    memory_s_xla_upper: float = 0.0
    collective_s: float = 0.0

    def __post_init__(self):
        self.compute_s = self.hlo_flops / (self.chips * TPU_PEAK_FLOPS)
        self.memory_s_xla_upper = self.hlo_bytes / (self.chips * TPU_HBM_BW)
        # XLA "bytes accessed" counts every HLO op's operands with CPU-level
        # fusion, inflating HBM traffic by >10x vs a TPU compile; the
        # analytic model (analytic_hbm_bytes) is the memory term, the XLA
        # number is kept as an upper bound.  Falls back to XLA if no model.
        mem_bytes = self.analytic_bytes or self.hlo_bytes
        self.memory_s = mem_bytes / (self.chips * TPU_HBM_BW)
        self.collective_s = self.collective_bytes / (
            self.chips * ICI_LINKS_PER_CHIP * TPU_ICI_LINK_BW
        )

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs: fraction of compiled compute that is
        'useful' model math (catches remat/redundancy waste)."""
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """compute term / bound: 1.0 = perfectly compute-bound (at roofline),
        lower = dominated by memory or collectives."""
        return self.compute_s / self.bound_s if self.bound_s else 0.0

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips, "hlo_flops": self.hlo_flops,
            "hlo_bytes": self.hlo_bytes,
            "collective_bytes": self.collective_bytes,
            "collectives": self.collectives, "model_flops": self.model_flops,
            "analytic_bytes": self.analytic_bytes,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "memory_s_xla_upper": self.memory_s_xla_upper,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def analytic_hbm_bytes(cfg, shape_spec, *, microbatches: int = 1,
                       attn_impl: str = "xla", remat: bool = True,
                       kv_cache_bytes: float = 0.0) -> float:
    """Modeled GLOBAL HBM traffic per step (bytes), summed over chips.

    Post-fusion accounting with explicit constants (documented here, used by
    EXPERIMENTS.md §Roofline):

    * weights: read once per fwd / recompute / bwd pass per microbatch
      (ZeRO-3 gathers land in HBM first), + fp32 optimizer read-modify-write;
    * activations: ~8 materialized (b, s, d) tensors per layer per pass
      (norm outs, attn in/out, mlp in/out, residuals) -- fused elementwise
      chains count once;
    * attention: "xla" materializes fp32 (b, h, s, s) scores (write + read,
      softmax in-register); "chunked"/flash keeps them in VMEM => 0 extra;
    * logits: (b, s, V) bf16 write+read (+ fp32 softmax pass in the loss);
    * decode: weights once + KV cache read + O(1) writes.

    Train multiplies fwd traffic by 3 (fwd + remat recompute + bwd) when
    remat is on, else 2.
    """
    P = cfg.param_count()
    bpe = 2  # bf16
    b = shape_spec.global_batch
    s = shape_spec.seq_len
    d = cfg.d_model

    if shape_spec.kind == "decode":
        # one token: all (active) weights stream once; KV cache streams once.
        weights = cfg.active_param_count() * bpe
        cache = kv_cache_bytes
        act = 20 * b * cfg.n_layers * d * bpe  # per-layer vectors, negligible
        return float(weights + cache + act)

    passes = 1 if shape_spec.kind == "prefill" else (3 if remat else 2)
    n_layers = cfg.n_layers + (cfg.n_encoder_layers or 0)
    weights = passes * microbatches * P * bpe
    acts = passes * 8 * n_layers * b * s * d * bpe
    attn = 0.0
    if attn_impl == "xla" and cfg.family not in ("ssm",):
        n_attn = n_layers if cfg.family != "hybrid" else max(
            1, cfg.n_layers // max(cfg.attn_every, 1))
        attn = passes * 2 * n_attn * b * cfg.n_heads * s * s * 4
    logits = 3 * b * s * cfg.vocab * bpe
    opt = 0.0
    if shape_spec.kind == "train":
        opt = 4 * P * 4  # m, v read+write in fp32 (+params RMW folded in)
    return float(weights + acts + attn + logits + opt)


def model_flops_for(cfg, shape_spec) -> float:
    """MODEL_FLOPS: 6*N*D for a train step (fwd+bwd), 2*N*D for forward-only
    prefill, 2*N_active per token for decode.  N = active params."""
    n = cfg.active_param_count()
    if shape_spec.kind == "train":
        tokens = shape_spec.global_batch * shape_spec.seq_len
        return 6.0 * n * tokens
    if shape_spec.kind == "prefill":
        tokens = shape_spec.global_batch * shape_spec.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape_spec.global_batch
