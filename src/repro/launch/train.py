"""End-to-end training driver: ``python -m repro.launch.train --arch <id>``.

Wires the whole stack together: config -> model -> synthetic data pipeline ->
AdamW (+schedule) -> fault-tolerant Trainer with checkpoint-restart, and
optionally an Arnold-scheduled mesh (``--devices N --mesh-shape dxm`` builds
an N-fake-device cluster, runs the MILP placement, permutes the mesh, and
trains under pjit).
"""

import argparse
import os
import sys


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm-2b")
    ap.add_argument("--full", action="store_true",
                    help="use the full published config (default: reduced)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--devices", type=int, default=0,
                    help="fake host devices for a sharded run (0 = single)")
    ap.add_argument("--mesh-shape", default="2x4",
                    help="dataxmodel for the sharded run")
    ap.add_argument("--arnold", action="store_true",
                    help="order mesh devices by the Arnold MILP placement")
    ap.add_argument("--scheduler", default="mip",
                    help="placement policy for --arnold: a registry name "
                         "(see repro.core.list_schedulers()) or a comma-"
                         "separated fallback chain, e.g. 'mip,topo-aware'")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}"
        )

    import jax
    from repro.configs import get_config
    from repro.data import SyntheticDataset
    from repro.models import ModelOptions, build_model
    from repro.models.whisper import N_FRAMES
    from repro.optim import AdamWConfig, get_schedule
    from repro.train import Trainer, TrainerConfig

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    opts = ModelOptions(
        compute_dtype="float32" if not args.devices else "bfloat16",
        remat=bool(args.full),
    )
    model = build_model(cfg, opts)

    extra = {}
    if cfg.family == "vlm":
        extra["patches"] = ((args.global_batch, cfg.n_patches, cfg.d_model), "float32")
    if cfg.family == "audio":
        extra["frames"] = ((args.global_batch, 24, cfg.d_model), "float32")
    ds = SyntheticDataset(cfg.vocab, args.seq_len, args.global_batch,
                          seed=args.seed, extra_specs=extra)
    schedule = get_schedule(cfg.lr_schedule, args.lr, warmup_steps=max(1, args.steps // 20),
                            total_steps=args.steps)
    opt = AdamWConfig(lr=schedule)

    trainer = Trainer(
        model, ds, opt, ckpt_dir=args.ckpt_dir,
        cfg=TrainerConfig(
            total_steps=args.steps, ckpt_every=args.ckpt_every,
            log_every=args.log_every, microbatches=args.microbatches,
            seed=args.seed,
        ),
        on_step=lambda h: print(
            f"step {h['step']:5d}  loss {h['loss']:.4f}  "
            f"gnorm {h['grad_norm']:.3f}  {h['step_time']*1e3:.0f} ms",
            flush=True,
        ),
    )

    if args.devices:
        # sharded run: optionally Arnold-ordered mesh
        from repro.core import (
            CharacterizationDB, Cluster, JobSpec, ModelSpec, ScheduleRequest,
            build_comm_matrix, get_scheduler,
        )
        from repro.launch.mesh import make_arnold_mesh, mesh_group_spread
        from repro.parallel import sharding as shd
        from repro.train import make_train_step

        d, m = (int(x) for x in args.mesh_shape.split("x"))
        assert d * m <= args.devices
        if args.arnold:
            nodes = args.devices // 8
            cluster = Cluster.uniform(max(2, nodes // 4), 4)
            mspec = ModelSpec(name=cfg.name, hidden=cfg.d_model,
                              layers=cfg.n_layers, vocab=cfg.vocab,
                              seq_len=args.seq_len, global_batch=args.global_batch,
                              d_ff=cfg.d_ff or 4 * cfg.d_model)
            job = JobSpec(n_gpus=d * m, tp=min(m, 8), pp=1, model=mspec)
            comm = build_comm_matrix(job)
            alpha, beta, unit = CharacterizationDB().affinity_for(comm)
            res = get_scheduler(args.scheduler).schedule(ScheduleRequest(
                comm=comm, cluster=cluster, alpha=alpha, beta=beta, unit=unit,
            ))
            mesh = make_arnold_mesh(res.placement, tp=job.tp, shape=(d, m),
                                    axes=("data", "model"))
            print(f"Arnold placement [{res.method}]: pods={res.n_pods_used()} "
                  f"spread(data axis)={mesh_group_spread(mesh, 'data', 32)}")
        else:
            mesh = jax.make_mesh((d, m), ("data", "model"))
        with shd.activate(mesh):
            trainer.step_fn = make_train_step(
                model, opt, mesh=mesh, microbatches=args.microbatches
            )(jax.eval_shape(lambda: {
                k: jax.numpy.asarray(v) for k, v in ds.batch(0).items()
            }))
            history = trainer.run()
    else:
        history = trainer.run()

    losses = trainer.losses()
    print(f"done: first logged loss {losses[0]:.4f} -> last {losses[-1]:.4f}")
    return 0 if losses[-1] < losses[0] else 1


if __name__ == "__main__":
    sys.exit(main())
