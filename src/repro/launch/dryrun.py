import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- everything below may import jax -----------------------------------------
"""Multi-pod dry-run (deliverable e): lower + compile every
(architecture x input shape x mesh) cell against the production meshes --
single-pod (16,16)=(data,model) and multi-pod (2,16,16)=(pod,data,model) --
on 512 placeholder host devices, recording memory_analysis / cost_analysis /
collective bytes for the roofline (deliverable g).

Usage:
    python -m repro.launch.dryrun --arch granite-8b --shape train_4k --mesh single
    python -m repro.launch.dryrun --all --mesh both --out reports/
"""

import argparse
import dataclasses
import json
import pathlib
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, SHAPES, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import (
    Roofline,
    analytic_hbm_bytes,
    inner_scan_flops,
    model_flops_for,
    parse_collective_bytes,
)
from repro.models import ModelOptions, build_model, input_specs
from repro.optim import AdamWConfig, init_opt_state
from repro.parallel import sharding as shd
from repro.train.train_step import cache_shardings
from repro.optim.adamw import adamw_update
from repro.train.train_step import loss_and_grads


#: per-shape implementation knobs (baseline configuration; §Perf iterates)
def options_for(arch: str, shape_name: str, overrides: dict | None = None) -> ModelOptions:
    kw = dict(param_dtype="bfloat16", compute_dtype="bfloat16", remat=True)
    if shape_name in ("prefill_32k",):
        kw.update(attn_impl="chunked", attn_chunk=1024)
    if overrides:
        kw.update(overrides)
    return ModelOptions(**kw)


def microbatches_for(arch: str, shape_name: str, mesh) -> int:
    if SHAPES[shape_name].kind != "train":
        return 1
    data = 1
    for a in ("pod", "data"):
        if a in mesh.shape:
            data *= mesh.shape[a]
    per_device = SHAPES[shape_name].global_batch // data
    cfg = get_config(arch)
    if cfg.is_moe:
        return max(1, per_device)    # MoE: 1 seq/device/microbatch (dispatch
                                     # + expert activations are the fat part)
    return max(1, per_device // 2)   # dense: 2 sequences per microbatch


def should_skip(arch: str, shape_name: str) -> str | None:
    cfg = get_config(arch)
    if shape_name == "long_500k" and not cfg.supports_long_context:
        return ("skipped: pure full-attention arch at 512k decode "
                "(KV cache exceeds HBM; see DESIGN.md §4)")
    return None


def _lower_cell(cfg, shape, mesh, opts, microbatches: int, rules=None,
                unroll_microbatches: bool = False):
    """Build the jitted step for one cell and lower it (no compile)."""
    model = build_model(cfg, opts)
    specs = input_specs(cfg, shape, opts)

    with shd.activate(mesh, rules=rules):
        params_sds = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
        p_shard = shd.param_shardings(params_sds, mesh, rules=rules)
        data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        if rules and rules.get("batch"):
            data_axes = tuple(rules["batch"])
        daxes = data_axes if len(data_axes) > 1 else data_axes[0]

        if shape.kind == "train":
            opt_sds = jax.eval_shape(init_opt_state, params_sds)
            o_shard = {
                "m": shd.opt_shardings(params_sds, mesh, rules=rules),
                "v": shd.opt_shardings(params_sds, mesh, rules=rules),
                "step": NamedSharding(mesh, P()),
            }
            b_shard = jax.tree.map(
                lambda leaf: NamedSharding(mesh, P(daxes)), specs
            )
            opt_cfg = AdamWConfig(lr=3e-4)

            def train_step(params, opt_state, batch):
                loss, metrics, grads = loss_and_grads(
                    model, params, batch, microbatches,
                    unroll=unroll_microbatches)
                params, opt_state, om = adamw_update(grads=grads, params=params,
                                                     state=opt_state, cfg=opt_cfg)
                return params, opt_state, {"loss": loss, **om}

            jitted = jax.jit(
                train_step,
                in_shardings=(p_shard, o_shard, b_shard),
                out_shardings=(p_shard, o_shard, None),
                donate_argnums=(0, 1),
            )
            return jitted.lower(params_sds, opt_sds, specs)

        if shape.kind == "prefill":

            def prefill(params, batch):
                logits, _ = model.forward(params, batch)
                return logits

            b_shard = jax.tree.map(lambda leaf: NamedSharding(mesh, P(daxes)), specs)
            jitted = jax.jit(prefill, in_shardings=(p_shard, b_shard))
            return jitted.lower(params_sds, specs)

        # decode
        def serve_step(params, cache, tokens):
            return model.decode_step(params, cache, tokens)

        c_shard = cache_shardings(specs["cache"], mesh, rules=rules,
                                  model=model)
        t_shard = NamedSharding(mesh, P())
        jitted = jax.jit(
            serve_step,
            in_shardings=(p_shard, c_shard, t_shard),
            out_shardings=(None, c_shard),
            donate_argnums=(1,),
        )
        return jitted.lower(params_sds, specs["cache"], specs["tokens"])


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             opt_overrides: dict | None = None, verbose: bool = True,
             with_analysis: bool | None = None,
             rule_overrides: dict | None = None,
             microbatches: int | None = None,
             analysis_true_microbatches: bool = False) -> dict:
    """Lower + compile one cell.

    Two compiles per single-pod cell:
    * production -- scanned layers + microbatched grad accumulation; its
      ``memory_analysis`` is the fits-on-device proof.
    * analysis -- unrolled layers, microbatches=1; XLA cost analysis counts
      ``while`` bodies once, so only this variant yields trip-count-true
      FLOPs / bytes / collective totals for the roofline.  Recurrent inner
      scans (xLSTM/Mamba2) that cannot unroll get the closed-form
      ``inner_scan_flops`` correction.
    """
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh_name = "multi" if multi_pod else "single"
    skip = should_skip(arch, shape_name)
    if skip:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": skip}
    if with_analysis is None:
        with_analysis = not multi_pod  # roofline table is single-pod only

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    opts = options_for(arch, shape_name, opt_overrides)
    mb = microbatches if microbatches is not None else microbatches_for(
        arch, shape_name, mesh)
    rules = None
    if rule_overrides:
        rules = shd.default_rules(mesh.axis_names)
        rules.update(rule_overrides)

    t0 = time.perf_counter()
    lowered = _lower_cell(cfg, shape, mesh, opts, mb, rules=rules)
    t_lower = time.perf_counter() - t0
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0 - t_lower
    mem = compiled.memory_analysis()

    record = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "status": "ok",
        "chips": chips, "microbatches": mb,
        "overrides": {"opts": opt_overrides or {}, "rules": 
                      {k: list(v) if isinstance(v, tuple) else v
                       for k, v in (rule_overrides or {}).items()}},
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes_per_device": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes_per_device": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes_per_device": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes_per_device": (
                (getattr(mem, "argument_size_in_bytes", 0) or 0)
                + (getattr(mem, "temp_size_in_bytes", 0) or 0)
            ),
        },
    }

    if with_analysis:
        a_over = dict(opt_overrides or {})
        a_over.update(scan_layers=False, attn_impl="xla")
        a_opts = options_for(arch, shape_name, a_over)
        # perf runs unroll the true microbatch count so grad-accumulation
        # effects (weight regathers per microbatch) appear in the totals
        a_mb = mb if analysis_true_microbatches else 1

        def analyse_at(n_layers: int, n_mb: int):
            acfg = cfg
            if n_layers != cfg.n_layers:
                acfg = dataclasses.replace(cfg, n_layers=n_layers)
            lowered_a = _lower_cell(acfg, shape, mesh, a_opts, microbatches=n_mb,
                                    rules=rules, unroll_microbatches=True)
            compiled_a = lowered_a.compile()
            c = compiled_a.cost_analysis()
            # Newer JAX returns a one-element list of per-program dicts.
            if isinstance(c, (list, tuple)):
                c = c[0] if c else {}
            coll = parse_collective_bytes(compiled_a.as_text())
            return (float(c.get("flops", 0.0)),
                    float(c.get("bytes accessed", 0.0)), coll)

        # Unrolled compile cost grows with n_layers x microbatches; both are
        # exactly linear per body (identical layers / identical microbatches),
        # so large cells are measured at small (L, M) grid points and fitted
        # bilinearly: cost = a + b*L + c*M + d*L*M.
        L_full, M_full = cfg.n_layers, a_mb
        layer_extrap = L_full > 48
        mb_extrap = M_full > 2
        if layer_extrap:
            step = max(cfg.attn_every or 1, cfg.slstm_every or 1, 1)
            l1 = max(step, (12 // step) * step or step)
            Ls = (l1, 2 * l1)
        else:
            Ls = (L_full,)
        Ms = (1, 2) if mb_extrap else (M_full,)
        grid = {(L, M): analyse_at(L, M) for L in Ls for M in Ms}
        extrapolated = layer_extrap or mb_extrap

        def fit(idx):
            def val(L, M):
                g = grid[(L, M)]
                return g[idx] if idx < 2 else g[2]

            def lin(p1, p2, x1, x2, x):
                return p1 + (p2 - p1) / (x2 - x1) * (x - x1) if x2 != x1 else p1

            if idx < 2:
                # numbers: fit M at each L, then L
                at_L = {
                    L: lin(val(L, Ms[0]), val(L, Ms[-1]), Ms[0], Ms[-1], M_full)
                    for L in Ls
                }
                return lin(at_L[Ls[0]], at_L[Ls[-1]], Ls[0], Ls[-1], L_full)
            # collectives: per-kind dict
            kinds = {k for g in grid.values() for k in g[2]}
            out = {}
            for k in kinds:
                at_L = {
                    L: lin(grid[(L, Ms[0])][2].get(k, 0),
                           grid[(L, Ms[-1])][2].get(k, 0), Ms[0], Ms[-1], M_full)
                    for L in Ls
                }
                out[k] = max(0.0, lin(at_L[Ls[0]], at_L[Ls[-1]], Ls[0], Ls[-1], L_full))
            return out

        a_flops, a_bytes, collectives = fit(0), fit(1), fit(2)
        cost = {"flops": a_flops, "bytes accessed": a_bytes}
        correction = inner_scan_flops(cfg, shape)
        if shape.kind == "train":
            correction *= 3.0  # fwd + bwd (~2x fwd)
        cache_bytes = 0.0
        if shape.kind == "decode":
            specs_d = input_specs(cfg, shape, opts)
            cache_bytes = float(sum(
                int(jnp.prod(jnp.array(l.shape))) * jnp.dtype(l.dtype).itemsize
                for l in jax.tree.leaves(specs_d["cache"])
            ))
        analytic = analytic_hbm_bytes(
            cfg, shape, microbatches=mb, attn_impl=opts.attn_impl,
            remat=opts.remat, kv_cache_bytes=cache_bytes,
        )
        rl = Roofline(
            arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
            hlo_flops=float(cost.get("flops", 0.0)) * chips + correction,
            hlo_bytes=float(cost.get("bytes accessed", 0.0)) * chips,
            collective_bytes=float(sum(collectives.values())) * chips,
            collectives={k: v * chips for k, v in collectives.items()},
            model_flops=model_flops_for(cfg, shape),
            analytic_bytes=analytic,
        )
        record["roofline"] = rl.to_dict()
        record["scan_flop_correction"] = correction
        record["analysis_depth_extrapolated"] = extrapolated

    if verbose:
        peak = record["memory"]["peak_bytes_per_device"] or 0
        extra = ""
        if with_analysis:
            rd = record["roofline"]
            extra = (f"  flops={rd['hlo_flops']:.3e}  coll={rd['collective_bytes']:.3e}B"
                     f"  dominant={rd['dominant']}")
        print(
            f"[{arch} x {shape_name} x {mesh_name}] OK  "
            f"compile={t_compile:.0f}s  peak={peak/2**30:.2f} GiB/dev" + extra,
            flush=True,
        )
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default=None)
    ap.add_argument("--shape", choices=sorted(SHAPES), default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true", help="sweep every cell")
    ap.add_argument("--out", default="reports", help="output dir for JSONL")
    ap.add_argument("--append", action="store_true")
    args = ap.parse_args()

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    cells = []
    if args.all:
        for arch in sorted(ARCHS):
            for shape in ["train_4k", "prefill_32k", "decode_32k", "long_500k"]:
                cells.append((arch, shape))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        cells = [(args.arch, args.shape)]

    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    out_file = out_dir / "dryrun.jsonl"
    mode = "a" if args.append else "w"
    failures = 0
    with open(out_file, mode) as fh:
        for arch, shape in cells:
            for multi in meshes:
                try:
                    rec = run_cell(arch, shape, multi)
                except Exception as e:  # noqa: BLE001 - report and continue
                    failures += 1
                    rec = {
                        "arch": arch, "shape": shape,
                        "mesh": "multi" if multi else "single",
                        "status": f"FAILED: {type(e).__name__}: {e}",
                    }
                    print(f"[{arch} x {shape} x {rec['mesh']}] FAILED: {e}",
                          flush=True)
                    traceback.print_exc()
                fh.write(json.dumps(rec) + "\n")
                fh.flush()
    print(f"wrote {out_file}; failures={failures}", flush=True)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
