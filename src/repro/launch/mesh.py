"""Production mesh construction + Arnold-aligned device ordering.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state): single-pod = (16, 16) over (data, model) = 256 chips;
multi-pod = (2, 16, 16) over (pod, data, model) = 512 chips.

``make_arnold_mesh`` is the paper's integration point: Arnold's MILP output
(a Placement) is converted to a logical->physical device permutation
(core/rank_assign.py) so mesh axes -- pjit's communication groups -- land on
the physical blocks the scheduler aligned.  On the fake-device dry-run the
"physical topology" is device-id order (contiguous id blocks = minipods),
mirroring how real TPU runtimes expose topology through device order.
"""

from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh

from repro.core.rank_assign import device_permutation
from repro.core.spread import Placement


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_arnold_mesh(
    placement: Placement,
    tp: int,
    shape: tuple,
    axes: tuple,
    devices=None,
    gpus_per_node: int = 8,
) -> Mesh:
    """Mesh whose device order follows an Arnold placement.

    The permutation orders devices by logical rank (pp, dp, tp); reshaped
    into ``shape`` (which must multiply to the permutation length), mesh
    axes then map onto scheduler-aligned physical blocks.
    """
    devices = list(jax.devices()) if devices is None else list(devices)
    perm = device_permutation(placement, tp, gpus_per_node)
    if len(perm) > len(devices):
        raise ValueError(f"placement needs {len(perm)} devices, have {len(devices)}")
    dev_arr = np.array([devices[i] for i in perm], dtype=object).reshape(shape)
    return Mesh(dev_arr, axes)


def mesh_device_minipods(mesh: Mesh, devices_per_pod: int) -> np.ndarray:
    """Minipod id of every device in the mesh (by id-block convention)."""
    ids = np.vectorize(lambda d: d.id)(mesh.devices)
    return ids // devices_per_pod


def mesh_group_spread(mesh: Mesh, axis: str, devices_per_pod: int) -> int:
    """Max spread (distinct minipods) over the communication groups of one
    mesh axis -- the JAX-side analogue of Eq. 3, used to verify that Arnold
    ordering actually reduces group spread on the fake-device cluster."""
    pods = mesh_device_minipods(mesh, devices_per_pod)
    axis_idx = mesh.axis_names.index(axis)
    moved = np.moveaxis(pods, axis_idx, 0)
    flat = moved.reshape(moved.shape[0], -1)
    # one group per column: devices varying along `axis` with others fixed
    spreads = [len(np.unique(flat[:, c])) for c in range(flat.shape[1])]
    return int(max(spreads))
