"""Flash attention Pallas TPU kernel: causal GQA with online softmax.

TPU adaptation (DESIGN.md §3): blocks are sized for VMEM and MXU alignment
-- q/k tiles of (block_q x head_dim) and (block_k x head_dim) with both
block sizes multiples of 128 at production shapes, fp32 accumulators held
in VMEM scratch across the contraction (kv) grid dimension, which is the
innermost ("arbitrary") axis so the (m, l, acc) carry is legal.

Grid: (batch, q_heads, sq/block_q, skv/block_k); GQA maps q-head h to
kv-head h // (hq/hkv) in the k/v index_maps -- no repeated-KV
materialization in HBM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
               causal: bool, sm_scale: float, block_q: int, block_k: int,
               n_kv_blocks: int, skv: int, sq: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)            # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)            # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * sm_scale  # (bq, bk)

    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    valid = k_pos < skv
    if causal:
        # causal offset: query i attends to keys <= i + (skv - sq)
        valid = valid & (q_pos + (skv - sq) >= k_pos)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=-1)
    acc_scr[...] = acc_scr[...] * corr[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new

    @pl.when(ik == n_kv_blocks - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False):
    """q: (b, hq, sq, hd); k/v: (b, hkv, skv, hd) -> (b, hq, sq, hd)."""
    b, hq, sq, hd = q.shape
    _, hkv, skv, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    n_q_blocks = pl.cdiv(sq, block_q)
    n_kv_blocks = pl.cdiv(skv, block_k)
    sm_scale = 1.0 / np.sqrt(hd)

    kernel = functools.partial(
        _fa_kernel, causal=causal, sm_scale=sm_scale, block_q=block_q,
        block_k=block_k, n_kv_blocks=n_kv_blocks, skv=skv, sq=sq,
    )
    grid = (b, hq, n_q_blocks, n_kv_blocks)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda ib, ih, iq, ik: (ib, ih // group, ik, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda ib, ih, iq, ik: (ib, ih // group, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd), lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),       # m: running max
            pltpu.VMEM((block_q,), jnp.float32),       # l: running denom
            pltpu.VMEM((block_q, hd), jnp.float32),    # acc: fp32 accumulator
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
