"""Fused RMSNorm Pallas kernel: one pass over rows, fp32 statistics in
registers, (block_rows x d) VMEM tiles.  Fuses the variance reduction with
the scale multiply so the activation is read from HBM exactly once."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, scale_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)               # (block_rows, d)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps) * scale_ref[...].astype(jnp.float32)).astype(
        o_ref.dtype
    )


def rmsnorm(x, scale, eps: float = 1e-5, block_rows: int = 256,
            interpret: bool = False):
    """x: (..., d) -> same shape; rows processed in VMEM tiles."""
    orig_shape = x.shape
    d = x.shape[-1]
    rows = int(np.prod(orig_shape[:-1])) if orig_shape[:-1] else 1
    x2 = x.reshape(rows, d)
    block_rows = min(block_rows, rows)
    grid = (pl.cdiv(rows, block_rows),)
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(x2, scale)
    return out.reshape(orig_shape)
