"""Mamba2 SSD chunk-scan Pallas kernel (the zamba2 hot spot).

One grid step processes one (batch, head, chunk) cell: the intra-chunk
quadratic-in-chunk attention-like matmuls run on the MXU from VMEM tiles of
(chunk x P) and (chunk x N), while the inter-chunk recurrent state S (P x N,
fp32) is carried across the sequential chunk axis in VMEM scratch --
exactly the chunkwise decomposition of ``models/zamba.mamba2_fwd``, with the
(t, u, H) gate tensor never materialized in HBM.

Grid: (batch, heads, n_chunks); chunk axis is "arbitrary" (carries S).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, b_ref, c_ref, dt_ref, loga_ref, y_ref, s_final_ref, s_scr,
                *, n_chunks: int, chunk: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    x = x_ref[0, 0].astype(jnp.float32)          # (cs, P)
    B = b_ref[0, 0].astype(jnp.float32)          # (cs, N)
    C = c_ref[0, 0].astype(jnp.float32)          # (cs, N)
    dt = dt_ref[0, 0].astype(jnp.float32)        # (cs,)
    loga = loga_ref[0, 0].astype(jnp.float32)    # (cs,)
    S = s_scr[...]                               # (P, N) carried fp32 state

    cum = jnp.cumsum(loga)
    decay = cum[:, None] - cum[None, :]
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    gate = jnp.where(tri, jnp.exp(decay), 0.0)
    cb = jnp.dot(C, B.T, preferred_element_type=jnp.float32)
    w = gate * cb * dt[None, :]
    y_intra = jnp.dot(w, x, preferred_element_type=jnp.float32)
    y_state = jnp.dot(C, S.T, preferred_element_type=jnp.float32) * jnp.exp(cum)[:, None]
    y_ref[0, 0] = (y_intra + y_state).astype(y_ref.dtype)

    w_state = jnp.exp(cum[-1] - cum) * dt        # (cs,)
    S_new = S * jnp.exp(cum[-1]) + jnp.dot(
        (x * w_state[:, None]).T, B, preferred_element_type=jnp.float32
    )
    s_scr[...] = S_new

    @pl.when(ic == n_chunks - 1)
    def _final():
        s_final_ref[0, 0] = S_new.astype(s_final_ref.dtype)


def ssd_chunk_scan(x, B, C, dt, loga, chunk: int = 128, interpret: bool = False):
    """x: (b, H, s, P); B/C: (b, H, s, N); dt/loga: (b, H, s).
    Returns (y (b, H, s, P), S_final (b, H, P, N))."""
    b, H, s, P = x.shape
    N = B.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    n_chunks = s // chunk

    kernel = functools.partial(_ssd_kernel, n_chunks=n_chunks, chunk=chunk)
    grid = (b, H, n_chunks)
    y, s_final = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda ib, ih, ic: (ib, ih, ic, 0)),
            pl.BlockSpec((1, 1, chunk, N), lambda ib, ih, ic: (ib, ih, ic, 0)),
            pl.BlockSpec((1, 1, chunk, N), lambda ib, ih, ic: (ib, ih, ic, 0)),
            pl.BlockSpec((1, 1, chunk), lambda ib, ih, ic: (ib, ih, ic)),
            pl.BlockSpec((1, 1, chunk), lambda ib, ih, ic: (ib, ih, ic)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda ib, ih, ic: (ib, ih, ic, 0)),
            pl.BlockSpec((1, 1, P, N), lambda ib, ih, ic: (ib, ih, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, H, s, P), x.dtype),
            jax.ShapeDtypeStruct((b, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, B, C, dt, loga)
    return y, s_final
