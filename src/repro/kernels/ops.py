"""jit'd public wrappers over the Pallas kernels with automatic backend
dispatch: TPU -> compiled Pallas kernel, anything else -> interpret mode
(tests) or the pure-jnp reference (production CPU path).

These are the entry points model code / hillclimbing configs call.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention as _fa_pallas
from repro.kernels.rmsnorm import rmsnorm as _rms_pallas
from repro.kernels.ssd_chunk import ssd_chunk_scan as _ssd_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k", "impl"))
def flash_attention(q, k, v, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, impl: str = "auto"):
    """Batched GQA flash attention.  impl: auto|pallas|interpret|ref."""
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        return ref.flash_attention_ref(q, k, v, causal=causal)
    interpret = impl == "interpret" or (impl == "pallas" and not _on_tpu())
    return _fa_pallas(q, k, v, causal=causal, block_q=block_q, block_k=block_k,
                      interpret=interpret)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "impl"))
def rmsnorm(x, scale, eps: float = 1e-5, block_rows: int = 256, impl: str = "auto"):
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        return ref.rmsnorm_ref(x, scale, eps)
    interpret = impl == "interpret" or (impl == "pallas" and not _on_tpu())
    return _rms_pallas(x, scale, eps=eps, block_rows=block_rows, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "impl"))
def ssd_chunk_scan(x, B, C, dt, loga, chunk: int = 128, impl: str = "auto"):
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        # vmap the per-(b,h) reference over batch and heads, scan over chunks
        b, H, s, P = x.shape
        N = B.shape[-1]
        cs = min(chunk, s)
        n = s // cs

        def per_bh(xbh, Bbh, Cbh, dtbh, logabh):
            def body(S, inp):
                xc, Bc, Cc, dtc, lac = inp
                y, S = ref.ssd_chunk_ref(xc, Bc, Cc, dtc, lac, S)
                return S, y

            S0 = jnp.zeros((P, N), jnp.float32)
            S, ys = jax.lax.scan(
                body, S0,
                (xbh.reshape(n, cs, P).astype(jnp.float32),
                 Bbh.reshape(n, cs, N).astype(jnp.float32),
                 Cbh.reshape(n, cs, N).astype(jnp.float32),
                 dtbh.reshape(n, cs).astype(jnp.float32),
                 logabh.reshape(n, cs).astype(jnp.float32)),
            )
            return ys.reshape(s, P).astype(x.dtype), S

        return jax.vmap(jax.vmap(per_bh))(x, B, C, dt, loga)
    interpret = impl == "interpret" or (impl == "pallas" and not _on_tpu())
    return _ssd_pallas(x, B, C, dt, loga, chunk=chunk, interpret=interpret)
