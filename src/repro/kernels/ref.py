"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` layer).

These are the ground truth the kernels are validated against in
``interpret=True`` mode across shape/dtype sweeps (tests/test_kernels.py),
and the implementations the XLA path uses on non-TPU backends.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def flash_attention_ref(q, k, v, causal: bool = True):
    """q: (b, hq, sq, hd); k/v: (b, hkv, skv, hd); GQA by head grouping.
    fp32 softmax, output in q.dtype."""
    b, hq, sq, hd = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    kq = jnp.repeat(k, group, axis=1)
    vq = jnp.repeat(v, group, axis=1)
    scores = jnp.einsum(
        "bhqd,bhkd->bhqk", q, kq, preferred_element_type=jnp.float32
    ) / np.sqrt(hd)
    skv = k.shape[2]
    if causal:
        mask = jnp.tril(jnp.ones((sq, skv), bool), k=skv - sq)
        scores = jnp.where(mask[None, None], scores, -jnp.inf)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, vq.astype(jnp.float32))
    return out.astype(q.dtype)


def rmsnorm_ref(x, scale, eps: float = 1e-5):
    """x: (..., d); fp32 statistics."""
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


def ssd_chunk_ref(x, B, C, dt, loga, S0):
    """One Mamba2 SSD chunk, single (batch, head):
    x: (cs, P), B/C: (cs, N), dt/loga: (cs,), S0: (P, N) carried state.
    Returns (y (cs, P), S1 (P, N)).  All fp32."""
    cs, P = x.shape
    cum = jnp.cumsum(loga)                       # (cs,)
    decay = cum[:, None] - cum[None, :]          # (t, u)
    tri = jnp.tril(jnp.ones((cs, cs), bool))
    gate = jnp.where(tri, jnp.exp(decay), 0.0)
    cb = C @ B.T                                 # (t, u)
    w = gate * cb * dt[None, :]
    y_intra = w @ x                              # (cs, P)
    y_state = (C @ S0.T) * jnp.exp(cum)[:, None]  # (cs, P)
    w_state = jnp.exp(cum[-1] - cum) * dt        # (cs,)
    S1 = S0 * jnp.exp(cum[-1]) + jnp.einsum("u,up,un->pn", w_state, x, B)
    return y_intra + y_state, S1
