"""Three-tier CLOS fabric: the paper's production interconnect (§2, Fig. 2b).

Nodes -> leaf switches (one per rack) -> spine switches (one *minipod* per
spine group) -> core switches.  Domains are minipods.  The fabric has full
bisection bandwidth at the core tier, so every pair of distinct minipods is
equidistant: traffic goes leaf -> spine -> core -> spine -> leaf no matter
which pods it connects.  That uniformity is why the paper can characterize
degradation purely as a function of the *number* of minipods spanned
(Fig. 4b/4c) -- the CLOS network model keeps that calibration.
"""

from __future__ import annotations

from typing import Sequence

from repro.topo.fabric import BaseFabric, register_fabric

#: hop distance between two distinct minipods (leaf/spine/core tier
#: crossings are symmetric; any inter-pod path transits the core once).
CROSS_POD_DISTANCE = 2


@register_fabric("clos")
class ClosFabric(BaseFabric):
    """The legacy 3-tier CLOS/minipod hierarchy, extracted verbatim from
    ``core/topology.py``: per-minipod node counts plus racks of
    ``nodes_per_rack`` retained for rank ordering."""

    kind = "clos"

    def __init__(self, nodes_per_minipod: Sequence[int], nodes_per_rack: int = 8):
        super().__init__(nodes_per_minipod)
        if nodes_per_rack < 1:
            raise ValueError(f"nodes_per_rack must be >= 1, got {nodes_per_rack}")
        self.nodes_per_rack = nodes_per_rack

    def coords(self, node_id: int) -> tuple[int, int, int]:
        """(minipod, rack, slot-in-rack)."""
        d = int(self.domain_index()[node_id])
        offset = node_id - self.domain_nodes(d)[0]
        return (d, offset // self.nodes_per_rack, offset % self.nodes_per_rack)

    def rack_of(self, node_id: int) -> int:
        return self.coords(node_id)[1]

    def domain_distance(self, a: int, b: int) -> int:
        return 0 if a == b else CROSS_POD_DISTANCE

    def diameter(self) -> int:
        return 0 if self.n_domains <= 1 else CROSS_POD_DISTANCE

    def distance_at_spread(self, spread: int) -> int:
        # All pods equidistant: any multi-pod set has the same diameter.
        return 0 if spread <= 1 or self.n_domains <= 1 else CROSS_POD_DISTANCE
