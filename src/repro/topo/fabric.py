"""Fabric protocol + registry: pluggable physical interconnects (DESIGN.md §9).

Arnold's spread objective was developed on one fabric -- the paper's
three-tier CLOS (§2, Fig. 2b) -- but the objective itself only needs a
notion of *locality domains* (sets of nodes with cheap mutual
communication) and a *hop distance* between those domains.  This module
makes that interface explicit so the scheduler stack, the spread metric,
and the network model can run on any interconnect:

* :class:`Fabric`          -- the protocol: node coordinates, locality
  domains, pairwise domain hop distance, bisection structure;
* :class:`BaseFabric`      -- shared implementation (domain index arrays,
  generic ``distance_at_spread``, contiguous scheduling blocks);
* a string-keyed registry (:func:`register_fabric`, :func:`get_fabric`,
  :func:`list_fabrics`) over fabric *classes*, mirroring the scheduler
  registry of :mod:`repro.core.scheduler`.

Concrete fabrics (``clos``, ``rail-only``, ``torus``, ``dragonfly``) live
in sibling modules and register themselves on import of
:mod:`repro.topo`.  The scheduling stack consumes fabrics through
:class:`repro.core.topology.Cluster`, whose "minipods" are exactly the
fabric's domains -- on ``clos`` this reproduces the legacy minipod
hierarchy bit-for-bit (parity asserted in tests/test_topo.py).
"""

from __future__ import annotations

import functools
import itertools
from typing import Protocol, Sequence, runtime_checkable

import numpy as np


@runtime_checkable
class Fabric(Protocol):
    """A physical interconnect at scheduling granularity.

    *Domains* are the fabric's locality unit (the generalization of the
    paper's minipod): communication inside a domain is treated as free by
    the spread metric, and crossing domains costs hop distance.  Node ids
    are dense ``0..n_nodes-1``; domain ids are dense ``0..n_domains-1``.
    """

    #: registry key of the fabric family ("clos", "torus", ...)
    kind: str

    @property
    def n_nodes(self) -> int: ...

    @property
    def n_domains(self) -> int: ...

    def domain_index(self) -> np.ndarray:
        """Node id -> domain id, as a dense int array of length n_nodes."""
        ...

    def domain_nodes(self, domain: int) -> list[int]:
        """Sorted node ids belonging to ``domain``."""
        ...

    def coords(self, node_id: int) -> tuple[int, ...]:
        """Physical coordinates of a node (fabric-specific axes)."""
        ...

    def domain_distance(self, a: int, b: int) -> int:
        """Hop distance between two domains (0 iff ``a == b``)."""
        ...

    def diameter(self) -> int:
        """Max domain-pairwise hop distance (>= 1 for multi-domain fabrics)."""
        ...

    def distance_at_spread(self, spread: int) -> int:
        """Tightest possible hop diameter of any set of ``spread`` domains.

        This is the optimistic locality profile the per-fabric network
        models use to turn a spread value into a degradation fraction
        when a concrete placement (with its exact hop diameter) is not
        in hand.
        """
        ...

    def partition(self, domains: Sequence[int]) -> tuple[list[int], list[int]]:
        """Bisection structure: split ``domains`` into two locality-coherent
        halves (used by recursive mapping heuristics)."""
        ...

    def scheduling_blocks(self, block_size: int) -> list[list[int]]:
        """Locality-coherent groups of <= ``block_size`` domains (the
        hierarchical tier's coarse units)."""
        ...


class BaseFabric:
    """Shared fabric mechanics: domain bookkeeping + generic distances.

    Subclasses provide ``kind``, per-domain node counts, and
    :meth:`domain_distance`; everything else has a correct (if not always
    tightest) default here.
    """

    kind = "base"

    def __init__(self, nodes_per_domain: Sequence[int]):
        counts = [int(c) for c in nodes_per_domain]
        if not counts or any(c <= 0 for c in counts):
            raise ValueError(f"nodes_per_domain must be positive, got {counts}")
        self._counts = counts
        self._domain_index = np.repeat(
            np.arange(len(counts)), counts
        ).astype(int)
        starts = np.concatenate([[0], np.cumsum(counts)])
        self._domain_nodes = [
            list(range(int(starts[d]), int(starts[d + 1])))
            for d in range(len(counts))
        ]

    # ------------------------------------------------------------- structure
    @property
    def n_nodes(self) -> int:
        return int(self._domain_index.size)

    @property
    def n_domains(self) -> int:
        return len(self._counts)

    def domain_index(self) -> np.ndarray:
        return self._domain_index

    def domain_nodes(self, domain: int) -> list[int]:
        return list(self._domain_nodes[domain])

    def coords(self, node_id: int) -> tuple[int, ...]:
        """Default coordinates: (domain, slot within domain)."""
        d = int(self._domain_index[node_id])
        return (d, node_id - self._domain_nodes[d][0])

    # ------------------------------------------------------------- distances
    def domain_distance(self, a: int, b: int) -> int:
        raise NotImplementedError

    def diameter(self) -> int:
        return self._diameter_cached()

    @functools.lru_cache(maxsize=None)
    def _diameter_cached(self) -> int:
        if self.n_domains <= 1:
            return 0
        return max(
            self.domain_distance(a, b)
            for a, b in itertools.combinations(range(self.n_domains), 2)
        )

    def distance_at_spread(self, spread: int) -> int:
        """Generic tightest q-domain ball diameter: for every center domain,
        take its ``spread`` nearest domains and measure that set's diameter;
        return the best center's value.  Exact and O(k^3)-ish -- fine at
        scheduling domain counts; regular fabrics override with closed
        forms."""
        q = int(spread)
        if q <= 1 or self.n_domains <= 1:
            return 0
        q = min(q, self.n_domains)
        return self._distance_at_spread_cached(q)

    @functools.lru_cache(maxsize=None)
    def _distance_at_spread_cached(self, q: int) -> int:
        k = self.n_domains
        dist = np.array(
            [[self.domain_distance(a, b) for b in range(k)] for a in range(k)]
        )
        best = None
        for center in range(k):
            ball = np.argsort(dist[center], kind="stable")[:q]
            diam = int(dist[np.ix_(ball, ball)].max())
            best = diam if best is None else min(best, diam)
        return int(best)

    # ------------------------------------------------------------- bisection
    def partition(self, domains: Sequence[int]) -> tuple[list[int], list[int]]:
        """Default bisection: split in id order (ids are laid out
        locality-major by construction in every built-in fabric)."""
        ds = list(domains)
        half = len(ds) // 2
        return ds[:half], ds[half:]

    def scheduling_blocks(self, block_size: int) -> list[list[int]]:
        if block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {block_size}")
        k = self.n_domains
        return [
            list(range(b, min(b + block_size, k)))
            for b in range(0, k, block_size)
        ]

    # ------------------------------------------------------------------ misc
    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (
            f"{type(self).__name__}(domains={self.n_domains}, "
            f"nodes={self.n_nodes})"
        )


# ---------------------------------------------------------------------------
# Registry (mirrors repro.core.scheduler's policy registry).
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, type] = {}
_ALIASES = {
    "rail": "rail-only",
    "railonly": "rail-only",
    "fat-tree": "clos",
    "minipod": "clos",
}


def _canon(name: str) -> str:
    key = name.strip().lower().replace("_", "-")
    return _ALIASES.get(key, key)


def register_fabric(name: str, cls: type | None = None, *, overwrite: bool = False):
    """Register a fabric class under ``name`` (usable as a decorator)."""

    def _register(obj: type) -> type:
        key = _canon(name)
        if key in _REGISTRY and not overwrite:
            raise ValueError(f"fabric {key!r} already registered")
        _REGISTRY[key] = obj
        return obj

    return _register if cls is None else _register(cls)


def get_fabric(name: str, *args, **kwargs) -> Fabric:
    """Instantiate the fabric registered under ``name``.

    Names are case-insensitive and ``_``/``-`` agnostic; construction
    arguments are forwarded to the fabric class
    (``get_fabric("torus", dims=(4, 4), nodes_per_domain=8)``).
    """
    key = _canon(name)
    try:
        cls = _REGISTRY[key]
    except KeyError:
        raise KeyError(
            f"unknown fabric {name!r}; available: {list_fabrics()}"
        ) from None
    return cls(*args, **kwargs)


def fabric_class(name: str) -> type:
    """The registered class itself (for classmethod constructors)."""
    key = _canon(name)
    if key not in _REGISTRY:
        raise KeyError(f"unknown fabric {name!r}; available: {list_fabrics()}")
    return _REGISTRY[key]


def list_fabrics() -> list[str]:
    """Canonical names of all registered fabrics (aliases excluded)."""
    return sorted(_REGISTRY)
