"""repro.topo: pluggable fabric subsystem (DESIGN.md §9).

* :mod:`repro.topo.fabric`    -- Fabric protocol + registry
* :mod:`repro.topo.clos`      -- 3-tier CLOS/minipod hierarchy (the paper's)
* :mod:`repro.topo.rail`      -- rail-only fabric (arXiv:2307.12169)
* :mod:`repro.topo.torus`     -- 2D/3D wrap-around ICI torus
* :mod:`repro.topo.dragonfly` -- dragonfly groups (arXiv:2407.20018 §3.2)
"""

from repro.topo.fabric import (
    BaseFabric,
    Fabric,
    fabric_class,
    get_fabric,
    list_fabrics,
    register_fabric,
)
from repro.topo.clos import ClosFabric
from repro.topo.dragonfly import DragonflyFabric
from repro.topo.rail import RailOnlyFabric
from repro.topo.torus import TorusFabric

import numpy as np


def comparable_fabric(kind: str, capacities, **kwargs) -> Fabric:
    """Build a fabric of family ``kind`` with the same total node count and
    (as closely as the family's structure allows) the same per-domain
    capacities as ``capacities`` -- the apples-to-apples constructor the
    cross-fabric benchmarks use.

    ``clos`` and ``rail-only`` take the capacities verbatim.  ``torus``
    factors the domain count into the most-square 2D grid (padding with
    empty-free domains is avoided by requiring an exact factorization of
    ``len(capacities)``; pass ``dims=...`` to override).  ``dragonfly``
    groups the domains into the most-square (groups x routers) split,
    carrying the per-router capacities verbatim.
    """
    caps = [int(c) for c in capacities]
    kind_c = kind.strip().lower().replace("_", "-")
    if kind_c in ("clos", "fat-tree", "minipod"):
        return ClosFabric(caps, **kwargs)
    if kind_c in ("rail-only", "rail", "railonly"):
        return RailOnlyFabric(caps, **kwargs)
    if kind_c == "torus":
        dims = kwargs.pop("dims", None) or _most_square(len(caps))
        return TorusFabric(dims, nodes_per_domain=caps, **kwargs)
    if kind_c == "dragonfly":
        groups, routers = _most_square(len(caps))
        return DragonflyFabric(
            n_groups=groups, routers_per_group=routers,
            nodes_per_router=caps, **kwargs,
        )
    raise KeyError(f"unknown fabric {kind!r}; available: {list_fabrics()}")


def _most_square(n: int) -> tuple[int, int]:
    """(a, b) with a*b == n and a <= b, a as large as possible."""
    a = int(np.sqrt(n))
    while n % a:
        a -= 1
    return (a, n // a)
