"""Dragonfly fabric (survey arXiv:2407.20018 §3.2).

Routers are arranged in *groups*: every router pair inside a group is
directly connected (full local mesh), and every group pair is connected by
at least one global link (full inter-group mesh at the group level).
Minimal routing is therefore at most local -> global -> local: one hop
inside a group, three hops between groups.

Domains are routers (each hosting ``nodes_per_router`` nodes), so the
spread metric sees both levels of the hierarchy: consolidating a
communication group onto one router costs 0, spilling across routers of
the same dragonfly group costs 1 hop, and spilling across groups costs
the 3-hop global detour -- the graded locality that distinguishes
dragonfly from the uniform CLOS core.
"""

from __future__ import annotations

from repro.topo.fabric import BaseFabric, register_fabric

#: minimal-route hop counts.
INTRA_GROUP_DISTANCE = 1   # direct local link between routers of a group
INTER_GROUP_DISTANCE = 3   # local -> global -> local


@register_fabric("dragonfly")
class DragonflyFabric(BaseFabric):
    """Dragonfly: ``n_groups`` groups x ``routers_per_group`` routers x
    ``nodes_per_router`` nodes.  Router (= domain) ids are group-major."""

    kind = "dragonfly"

    def __init__(
        self,
        n_groups: int,
        routers_per_group: int = 4,
        nodes_per_router=8,
    ):
        """``nodes_per_router`` is a scalar (regular fabric) or a list of
        length ``n_groups * routers_per_group`` (per-router capacities, for
        capacity-matched benchmark comparisons)."""
        if n_groups < 1 or routers_per_group < 1:
            raise ValueError(
                f"need positive group/router counts, got "
                f"{n_groups}x{routers_per_group}"
            )
        n_routers = n_groups * routers_per_group
        if isinstance(nodes_per_router, int):
            caps = [nodes_per_router] * n_routers
        else:
            caps = [int(c) for c in nodes_per_router]
            if len(caps) != n_routers:
                raise ValueError(
                    f"nodes_per_router list must have {n_routers} entries "
                    f"(= n_groups * routers_per_group), got {len(caps)}"
                )
        super().__init__(caps)
        self.n_groups = n_groups
        self.routers_per_group = routers_per_group

    # ------------------------------------------------------------- structure
    def group_of(self, domain: int) -> int:
        return domain // self.routers_per_group

    def coords(self, node_id: int) -> tuple[int, int, int]:
        """(group, router within group, slot within router)."""
        d = int(self.domain_index()[node_id])
        slot = node_id - self.domain_nodes(d)[0]
        return (self.group_of(d), d % self.routers_per_group, slot)

    # ------------------------------------------------------------- distances
    def domain_distance(self, a: int, b: int) -> int:
        if a == b:
            return 0
        if self.group_of(a) == self.group_of(b):
            return INTRA_GROUP_DISTANCE
        return INTER_GROUP_DISTANCE

    def diameter(self) -> int:
        if self.n_groups > 1:
            return INTER_GROUP_DISTANCE
        return INTRA_GROUP_DISTANCE if self.routers_per_group > 1 else 0

    def distance_at_spread(self, spread: int) -> int:
        if spread <= 1 or self.n_domains <= 1:
            return 0
        if spread <= self.routers_per_group:
            return INTRA_GROUP_DISTANCE  # fits one group's local mesh
        return INTER_GROUP_DISTANCE

    # ------------------------------------------------------------- bisection
    def partition(self, domains):
        """Split at a group boundary when possible (group-major ids make
        the id-order split already group-coherent)."""
        ds = sorted(domains)
        half = len(ds) // 2
        return ds[:half], ds[half:]
