"""Rail-only fabric (arXiv:2307.12169).

A rail-optimized GPU cluster removes the CLOS core/spine layers: GPU ``i``
of every node in an HB-domain group connects to rail switch ``i``, so the
group's nodes reach each other in one switch hop on every rail, while
traffic *between* rail groups has no dedicated switching layer at all --
it must be forwarded through GPUs (NVLink hop + double NIC transit).

Domains are rail groups.  Intra-group distance is 0 (one rail-switch hop
is the fabric's locality unit); cross-group distance models the
forwarding detour and is deliberately larger than a CLOS core transit,
which is what makes the spread objective *more* valuable here: a group
that straddles rails pays far more than one that straddles minipods.
"""

from __future__ import annotations

from typing import Sequence

from repro.topo.fabric import BaseFabric, register_fabric

#: hop distance between distinct rail groups: NIC -> rail switch -> GPU
#: forward (NVLink) -> NIC -> rail switch -> NIC, modeled as 3 hops vs the
#: CLOS core transit's 2.
CROSS_RAIL_DISTANCE = 3


@register_fabric("rail-only")
class RailOnlyFabric(BaseFabric):
    """Rail-only cluster: domains are rail groups of ``rail_width``-node
    HB domains sharing a set of rail switches."""

    kind = "rail-only"

    def __init__(self, nodes_per_group: Sequence[int], rails: int = 8):
        super().__init__(nodes_per_group)
        if rails < 1:
            raise ValueError(f"rails must be >= 1, got {rails}")
        self.rails = rails

    def coords(self, node_id: int) -> tuple[int, int]:
        """(rail group, slot within group)."""
        return super().coords(node_id)

    def domain_distance(self, a: int, b: int) -> int:
        return 0 if a == b else CROSS_RAIL_DISTANCE

    def diameter(self) -> int:
        return 0 if self.n_domains <= 1 else CROSS_RAIL_DISTANCE

    def distance_at_spread(self, spread: int) -> int:
        return 0 if spread <= 1 or self.n_domains <= 1 else CROSS_RAIL_DISTANCE
