"""2D/3D torus fabric (TPU-style ICI, DESIGN.md §3).

Domains are torus vertices -- an ICI "board" of ``nodes_per_domain``
nodes -- arranged in a wrap-around 2D or 3D grid.  Unlike CLOS, distance
is *not* uniform: hop distance between vertices is the wrap-around
Manhattan distance, so locality is graded and the tightest q-vertex
neighbourhood (a sub-box) matters.  The per-fabric network model
(:class:`repro.core.netmodel.TorusNetModel`) runs on the
``TPU_ICI_BW`` per-link constant that the roofline analysis already uses.
"""

from __future__ import annotations

from typing import Sequence

import numpy as np

from repro.topo.fabric import BaseFabric, register_fabric


@register_fabric("torus")
class TorusFabric(BaseFabric):
    """Wrap-around grid of ICI domains.

    ``dims`` is the grid shape (2 or 3 axes); ``nodes_per_domain`` is the
    node count of every vertex (scalar) or per-vertex counts in row-major
    vertex order (sequence of length ``prod(dims)``).
    """

    kind = "torus"

    def __init__(self, dims: Sequence[int], nodes_per_domain: "int | Sequence[int]" = 8):
        dims = tuple(int(d) for d in dims)
        if len(dims) not in (2, 3) or any(d < 1 for d in dims):
            raise ValueError(f"dims must be 2 or 3 positive axes, got {dims}")
        n_vertices = int(np.prod(dims))
        if isinstance(nodes_per_domain, int):
            counts = [nodes_per_domain] * n_vertices
        else:
            counts = list(nodes_per_domain)
            if len(counts) != n_vertices:
                raise ValueError(
                    f"nodes_per_domain has {len(counts)} entries, "
                    f"grid {dims} has {n_vertices} vertices"
                )
        super().__init__(counts)
        self.dims = dims
        # Vertex id <-> grid coordinate, row-major (id order is the
        # lexicographic walk, so contiguous id ranges are grid rows).
        self._vertex_coords = np.stack(
            np.unravel_index(np.arange(n_vertices), dims), axis=1
        )

    # ------------------------------------------------------------- structure
    def domain_coords(self, domain: int) -> tuple[int, ...]:
        return tuple(int(c) for c in self._vertex_coords[domain])

    def coords(self, node_id: int) -> tuple[int, ...]:
        """Grid coordinate of the node's vertex + slot within the vertex."""
        d = int(self.domain_index()[node_id])
        slot = node_id - self.domain_nodes(d)[0]
        return self.domain_coords(d) + (slot,)

    # ------------------------------------------------------------- distances
    def domain_distance(self, a: int, b: int) -> int:
        ca, cb = self._vertex_coords[a], self._vertex_coords[b]
        total = 0
        for axis, size in enumerate(self.dims):
            delta = abs(int(ca[axis]) - int(cb[axis]))
            total += min(delta, size - delta)  # wrap-around link
        return total

    def diameter(self) -> int:
        return sum(d // 2 for d in self.dims)

    # ------------------------------------------------------------- bisection
    def partition(self, domains: Sequence[int]) -> tuple[list[int], list[int]]:
        """Split along the axis with the largest coordinate extent, keeping
        each half a contiguous slab (minimizes wrap-around cut links)."""
        ds = list(domains)
        if len(ds) < 2:
            return ds, []
        coords = self._vertex_coords[ds]
        extents = coords.max(axis=0) - coords.min(axis=0)
        axis = int(np.argmax(extents))
        order = sorted(ds, key=lambda d: (int(self._vertex_coords[d][axis]), d))
        half = len(order) // 2
        return order[:half], order[half:]
