"""Fault-tolerant training loop: checkpoint-restart, auto-resume after
simulated node failures, prefetched data, scheduler-integrated launch.

The loop is deliberately crash-safe: state lives in (checkpoint, step) and
data is a pure function of step, so ``Trainer.run`` can be killed at any
point and re-invoked to continue bit-exactly (tests/test_trainer.py kills it
mid-run to prove it).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import numpy as np

from repro.checkpoint import Checkpointer
from repro.data import Prefetcher, SyntheticDataset
from repro.optim import AdamWConfig, init_opt_state
from repro.train.train_step import make_train_step


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    log_every: int = 10
    keep_ckpts: int = 3
    microbatches: int = 1
    async_ckpt: bool = False
    seed: int = 0


class FaultInjector:
    """Deterministic failure schedule for tests/examples: raises at given
    steps, once each (models a node crash surfacing as a step exception)."""

    def __init__(self, fail_at: list[int]):
        self.fail_at = set(fail_at)
        self.fired: set[int] = set()

    def maybe_fail(self, step: int):
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise RuntimeError(f"injected node failure at step {step}")


class Trainer:
    def __init__(
        self,
        model,
        dataset: SyntheticDataset,
        opt_cfg: AdamWConfig,
        ckpt_dir,
        cfg: TrainerConfig = TrainerConfig(),
        fault_injector: Optional[FaultInjector] = None,
        on_step: Optional[Callable] = None,
    ):
        self.model = model
        self.dataset = dataset
        self.opt_cfg = opt_cfg
        self.cfg = cfg
        self.ckpt = Checkpointer(ckpt_dir, keep_last=cfg.keep_ckpts,
                                 use_async=cfg.async_ckpt)
        self.fault = fault_injector
        self.on_step = on_step
        self.step_fn = make_train_step(model, opt_cfg, microbatches=cfg.microbatches)
        self.history: list[dict] = []

    # ------------------------------------------------------------------ state
    def _init_state(self):
        params = self.model.init(jax.random.PRNGKey(self.cfg.seed))
        return params, init_opt_state(params)

    def _restore_or_init(self):
        latest = self.ckpt.latest_step()
        params_t, opt_t = jax.eval_shape(self._init_state)
        if latest is None:
            params, opt_state = self._init_state()
            return params, opt_state, 0
        state = self.ckpt.restore({"params": params_t, "opt": opt_t}, step=latest)
        return state["params"], state["opt"], latest

    # -------------------------------------------------------------------- run
    def run(self, max_retries: int = 3) -> list[dict]:
        """Train to total_steps, restarting from the last checkpoint on any
        step failure (up to ``max_retries`` consecutive times)."""
        retries = 0
        while True:
            try:
                self._run_once()
                self.ckpt.wait()
                return self.history
            except RuntimeError as e:
                retries += 1
                if retries > max_retries:
                    raise
                # fault-tolerance path: restore from the last checkpoint
                self.history.append({"event": "restart", "error": str(e)})

    def _run_once(self):
        params, opt_state, start = self._restore_or_init()
        prefetch = Prefetcher(self.dataset, start_step=start)
        try:
            step = start
            while step < self.cfg.total_steps:
                data_step, batch = prefetch.next()
                assert data_step == step, (data_step, step)
                if self.fault is not None:
                    self.fault.maybe_fail(step)
                t0 = time.perf_counter()
                params, opt_state, metrics = self.step_fn(params, opt_state, batch)
                step += 1
                if step % self.cfg.log_every == 0 or step == self.cfg.total_steps:
                    loss = float(metrics["loss"])
                    self.history.append(
                        {
                            "step": step,
                            "loss": loss,
                            "grad_norm": float(metrics["grad_norm"]),
                            "step_time": time.perf_counter() - t0,
                        }
                    )
                    if self.on_step:
                        self.on_step(self.history[-1])
                if step % self.cfg.ckpt_every == 0 or step == self.cfg.total_steps:
                    self.ckpt.save(step, {"params": params, "opt": opt_state})
        finally:
            prefetch.close()

    def losses(self) -> list[float]:
        return [h["loss"] for h in self.history if "loss" in h]
