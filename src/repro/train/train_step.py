"""train_step / serve_step builders: loss + grad + AdamW update under pjit,
with microbatched gradient accumulation and donated buffers.

``make_train_step`` returns a jit-compiled function whose in/out shardings
implement DP over (pod, data), TP/EP over model, and ZeRO-1 optimizer-state
sharding -- the pjit realization of the hybrid parallelism whose
communication groups Arnold schedules.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state
from repro.parallel import sharding as shd


def loss_and_grads(model, params, batch, microbatches: int = 1,
                   unroll: bool = False):
    """Value+grad with optional gradient accumulation over microbatches.

    Default: sequential ``lax.scan`` (constant HLO size).  ``unroll=True``
    uses a python loop instead -- identical math, but every microbatch is
    explicit in the HLO, which the dry-run's analysis compile needs for
    trip-count-true cost analysis (XLA counts ``while`` bodies once).
    """
    if microbatches <= 1:
        (loss, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(params, batch)
        return loss, metrics, grads

    b = batch["tokens"].shape[0]
    assert b % microbatches == 0, (b, microbatches)
    mb = b // microbatches
    split = jax.tree.map(
        lambda a: a.reshape((microbatches, mb) + a.shape[1:]), batch
    )

    def one(params, mbatch):
        (loss, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(params, mbatch)
        return loss, metrics, grads

    def body(carry, mbatch):
        loss_acc, grads_acc = carry
        loss, metrics, grads = one(params, mbatch)
        grads_acc = jax.tree.map(
            lambda a, g: a + g.astype(jnp.float32), grads_acc, grads
        )
        return (loss_acc + loss, grads_acc), metrics

    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    if unroll:
        carry = (jnp.zeros(()), zeros)
        for i in range(microbatches):
            mbatch = jax.tree.map(lambda a: a[i], split)
            carry, metrics = body(carry, mbatch)
        loss_sum, grads_sum = carry
    else:
        (loss_sum, grads_sum), metrics = jax.lax.scan(body, (0.0, zeros), split)
        metrics = jax.tree.map(lambda m: m[-1], metrics)
    inv = 1.0 / microbatches
    grads = jax.tree.map(lambda g: g * inv, grads_sum)
    return loss_sum * inv, metrics, grads


def make_train_step(model, opt_cfg: AdamWConfig, mesh=None, microbatches: int = 1,
                    donate: bool = True):
    """Build the jitted train step.  With a mesh, in/out shardings are the
    param/opt rules from ``parallel.sharding`` and batch is DP-sharded."""

    def train_step(params, opt_state, batch):
        loss, metrics, grads = loss_and_grads(model, params, batch, microbatches)
        params, opt_state, opt_metrics = adamw_update(params, grads, opt_state, opt_cfg)
        return params, opt_state, {"loss": loss, **metrics, **opt_metrics}

    if mesh is None:
        return jax.jit(train_step, donate_argnums=(0, 1) if donate else ())

    params_shape = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    p_shard = shd.param_shardings(params_shape, mesh)
    o_shard = {
        "m": shd.opt_shardings(params_shape, mesh),
        "v": shd.opt_shardings(params_shape, mesh),
        "step": NamedSharding(mesh, P()),
    }
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def batch_shardings(batch_shape):
        return jax.tree.map(
            lambda leaf: NamedSharding(
                mesh,
                P(data_axes if len(data_axes) > 1 else data_axes[0])
                if leaf.shape and leaf.shape[0] % _prod(mesh, data_axes) == 0
                else P(),
            ),
            batch_shape,
        )

    metric_shard = NamedSharding(mesh, P())

    def jitted(batch_shape):
        return jax.jit(
            train_step,
            in_shardings=(p_shard, o_shard, batch_shardings(batch_shape)),
            out_shardings=(p_shard, o_shard, None),
            donate_argnums=(0, 1) if donate else (),
        )

    jitted.param_shardings = p_shard
    jitted.opt_shardings = o_shard
    jitted.batch_shardings = batch_shardings
    return jitted


def _prod(mesh, axes):
    out = 1
    for a in axes:
        out *= mesh.shape[a]
    return out


def make_serve_step(model, mesh=None):
    """Jitted single-token decode (cache donated for in-place update)."""

    def serve_step(params, cache, tokens):
        return model.decode_step(params, cache, tokens)

    if mesh is None:
        return jax.jit(serve_step, donate_argnums=(1,))
    params_shape = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    p_shard = shd.param_shardings(params_shape, mesh)

    def jitted(cache_shape, tokens_shape):
        c_shard = cache_shardings(cache_shape, mesh, model=model)
        t_shard = jax.tree.map(lambda _: NamedSharding(mesh, P()), tokens_shape)
        return jax.jit(
            serve_step,
            in_shardings=(p_shard, c_shard, None),
            out_shardings=(None, c_shard),
            donate_argnums=(1,),
        )

    jitted.param_shardings = p_shard
    return jitted


def cache_shardings(cache_shape, mesh, rules=None, model=None):
    """KV caches / recurrent states: batch over data axes; KV heads over
    ``model`` when the GQA head count divides it, else the sequence dim
    (flash-decoding-style partial attention); SSM/mLSTM heads over ``model``.

    Logical names come from the model's ``cache_axes()`` (exact layout);
    falls back to a rank-based heuristic for foreign cache pytrees.
    """
    rules = rules or shd.default_rules(mesh.axis_names)
    model_size = mesh.shape.get("model", 1)

    def resolve_names(names, shape):
        local_rules = dict(rules)
        names = list(names)
        # decide kv_heads vs kv_seq by divisibility
        if "kv_heads" in names:
            hd_idx = names.index("kv_heads")
            if shape[hd_idx] % model_size == 0:
                local_rules["kv_seq"] = ()
            else:
                names[hd_idx] = None
                local_rules["kv_seq"] = ("model",)
        local_rules.setdefault("kv_seq", ())
        local_rules.setdefault("layers", ())
        local_rules.setdefault("units", ())
        local_rules.setdefault("per_unit", ())
        spec = shd.resolve_spec(names, shape, mesh, local_rules)
        return NamedSharding(mesh, spec)

    if model is not None and hasattr(model, "cache_axes"):
        axes = model.cache_axes()

        def g(names, leaf):
            if not leaf.shape:
                return NamedSharding(mesh, P())
            return resolve_names(names, leaf.shape)

        return jax.tree.map(g, axes, cache_shape,
                            is_leaf=lambda x: isinstance(x, tuple))

    def f(path, leaf):
        if not leaf.shape:
            return NamedSharding(mesh, P())
        names: list = [None] * len(leaf.shape)
        path_s = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        ndim = len(leaf.shape)
        if ("kv" in path_s or "cross" in path_s) and ndim >= 4:
            names[-4] = "batch"
            names[-2] = "kv_heads"
            names[-3] = "kv_seq"
        elif path_s.endswith("S") or "states" in path_s:
            if ndim >= 4:
                names[-3] = "ssm_heads"
        return resolve_names(names, leaf.shape)

    return jax.tree_util.tree_map_with_path(f, cache_shape)
