from repro.train.train_step import (
    cache_shardings,
    loss_and_grads,
    make_serve_step,
    make_train_step,
)
from repro.train.trainer import FaultInjector, Trainer, TrainerConfig

__all__ = [
    "cache_shardings", "loss_and_grads", "make_serve_step", "make_train_step",
    "FaultInjector", "Trainer", "TrainerConfig",
]
