"""Pipeline parallelism via shard_map + collective_permute (GPipe schedule).

This realizes the PP dimension of the comm matrix (columns) in JAX: stages
are sharded over a ``stage`` mesh axis, microbatches stream through a
``lax.scan`` of compute->``ppermute`` ticks, and reverse-mode AD through the
scan yields the backward pipeline automatically (ppermute's transpose is the
reverse ppermute), i.e. a fwd-all/bwd-all GPipe with bubble fraction
(S-1)/(m+S-1).

The boundary traffic per tick is exactly the paper's Eq. 13 PP volume
(2*mb*s*h bytes counting fwd+bwd), which is what the Arnold scheduler's
``v_p`` models -- see tests/test_pipeline.py for the volume assertion.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_forward(
    stage_fn: Callable,        # (stage_params, x) -> x  : one stage's compute
    n_stages: int,
    axis_name: str = "stage",
):
    """Build fn(stacked_stage_params, microbatched_x) -> y, to be called
    INSIDE shard_map where ``axis_name`` has size n_stages.

    x: (m, mb, ...) microbatches, identical on all stages (stage 0 consumes
    them); returns (m, mb, ...) outputs valid on the LAST stage.
    """

    def fn(stage_params, x_mb):
        stage = jax.lax.axis_index(axis_name)
        m = x_mb.shape[0]
        n_ticks = m + n_stages - 1
        perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

        buf = jnp.zeros_like(x_mb[0])
        outs = jnp.zeros((m,) + x_mb.shape[1:], x_mb.dtype)

        def tick(carry, t):
            buf, outs = carry
            # stage 0 ingests microbatch t (while t < m); others use buf
            mb_in = jax.lax.dynamic_index_in_dim(
                x_mb, jnp.clip(t, 0, m - 1), keepdims=False
            )
            x_in = jnp.where(stage == 0, mb_in, buf)
            y = stage_fn(stage_params, x_in)
            # last stage writes its result for microbatch (t - (S-1))
            out_idx = t - (n_stages - 1)
            write = (stage == n_stages - 1) & (out_idx >= 0)
            outs = jax.lax.cond(
                write,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, jnp.clip(out_idx, 0, m - 1), 0
                ),
                lambda o: o,
                outs,
            )
            # boundary send-recv: stage i -> i+1 (Eq. 13 traffic)
            buf = jax.lax.ppermute(y, axis_name, perm)
            return (buf, outs), None

        (buf, outs), _ = jax.lax.scan(tick, (buf, outs), jnp.arange(n_ticks))
        # only the last stage holds real outputs; broadcast via masked psum
        # so out_specs=P() is well-defined on every stage
        outs = jax.lax.psum(
            jnp.where(stage == n_stages - 1, outs, jnp.zeros_like(outs)), axis_name
        )
        return outs

    return fn


def make_pp_loss_fn(
    embed_fn: Callable,        # (params, batch) -> x0 (m, mb, s, d)
    stage_fn: Callable,        # (stage_params, x) -> x
    head_loss_fn: Callable,    # (params, x_out, batch) -> scalar loss
    mesh: Mesh,
    n_stages: int,
    axis_name: str = "stage",
):
    """End-to-end pipelined loss under shard_map: stage params sharded over
    the stage axis (leading dim), everything else replicated."""
    pipe = pipeline_forward(stage_fn, n_stages, axis_name)

    def loss(params, batch):
        def inner(stage_params, shared_params, batch):
            x0 = embed_fn(shared_params, batch)
            x_stage = jax.tree.map(lambda a: a[0], stage_params)  # local slice
            y = pipe(x_stage, x0)
            return head_loss_fn(shared_params, y, batch)

        return shard_map(
            inner,
            mesh=mesh,
            in_specs=(P(axis_name), P(), P()),
            out_specs=P(),
            check_rep=False,
        )(params["stages"], params["shared"], batch)

    return loss


def pp_boundary_bytes(mb: int, seq: int, d_model: int, n_microbatches: int,
                      bytes_per_el: int = 2) -> int:
    """Eq. 13 check: bytes crossing one PP boundary per step (fwd + bwd)."""
    return 2 * mb * seq * d_model * n_microbatches * bytes_per_el
