"""Logical-axis sharding rules (DP/TP/EP/SP) for the production meshes.

Models annotate activations with *logical* axis names via :func:`lshard`
(e.g. ``lshard(x, "batch", "seq", "embed")``); a rule table maps logical
names to physical mesh axes.  Rules are resolved *shape-aware*: a mapping
that does not divide the dimension evenly (e.g. 2 KV heads over a 16-way
``model`` axis) degrades to replication for that dim instead of failing --
this is what lets one rule table serve all 10 architectures.

Parameter sharding is path-based (:func:`param_spec`): attention/FFN weights
are tensor-parallel over ``model``, expert stacks are expert-parallel over
``model``, embeddings/LM head are vocab-parallel, and optimizer state is
additionally ZeRO-1 sharded over the data axes (:func:`opt_spec`).

The active mesh + rules live in a context (:func:`activate`) so the same
model code traces correctly under ``jit``, ``lower()`` for the dry-run, and
plain eager smoke tests (no mesh -> no-op).
"""

from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------------
# Rule tables.  Values are mesh-axis names or tuples of them.
# ---------------------------------------------------------------------------

def default_rules(mesh_axes: Sequence[str], sequence_parallel: bool = False) -> dict:
    data_axes = tuple(a for a in ("pod", "data") if a in mesh_axes)
    rules = {
        "batch": data_axes,                # DP over pod x data
        "seq": (),                         # replicated (SP overrides)
        "seq_sp": (),                      # residual-stream seq (Megatron SP
                                           # region between attn/mlp blocks)
        "embed": (),                       # activations replicated on d_model
        "heads": ("model",),               # TP over attention heads
        "kv_heads": ("model",),            # degrades to replicate if indivisible
        "head_dim": (),
        "ffn": ("model",),                 # TP over FFN hidden
        "experts": ("model",),             # EP over expert stack
        "expert_ff": (),                   # per-expert hidden stays local
        "vocab": ("model",),               # vocab-parallel embeddings/logits
        "ssm_heads": ("model",),
        "ssm_state": (),
        "zero": data_axes,                 # ZeRO-1 optimizer-state axis
        "fsdp": data_axes,                 # ZeRO-3 weight sharding over DP
                                           # (the paper's §2 "ZeRO shards
                                           # model weights ... all-gather /
                                           # reduce-scatter"); () disables
        "stage": (),                       # pipeline stage (shard_map PP only)
    }
    if sequence_parallel:
        # SP: shard activation seq dim over `model` between attention/FFN
        # blocks (norms/residuals); attention itself re-gathers via `heads`.
        rules["seq"] = ("model",)
    return rules


class _Ctx(threading.local):
    mesh: Optional[Mesh] = None
    rules: Optional[dict] = None


_CTX = _Ctx()


@contextlib.contextmanager
def activate(mesh: Mesh, rules: Optional[dict] = None, sequence_parallel: bool = False):
    """Enable sharding constraints for model code traced in this context."""
    prev = (_CTX.mesh, _CTX.rules)
    _CTX.mesh = mesh
    _CTX.rules = rules or default_rules(mesh.axis_names, sequence_parallel)
    try:
        yield
    finally:
        _CTX.mesh, _CTX.rules = prev


def active_mesh() -> Optional[Mesh]:
    return _CTX.mesh


def data_axis_names() -> tuple:
    if _CTX.mesh is None:
        return ()
    return tuple(a for a in ("pod", "data") if a in _CTX.mesh.axis_names)


# ---------------------------------------------------------------------------
# Resolution: logical names -> PartitionSpec, shape-aware.
# ---------------------------------------------------------------------------

def _axis_size(mesh: Mesh, names) -> int:
    size = 1
    for n in names:
        size *= mesh.shape[n]
    return size


def resolve_spec(logical: Sequence[Optional[str]], shape: Sequence[int], mesh: Mesh,
                 rules: dict) -> P:
    parts = []
    used: set[str] = set()
    for dim, name in zip(shape, logical):
        entry = rules.get(name, ()) if name else ()
        entry = tuple(e for e in (entry if isinstance(entry, tuple) else (entry,)) if e)
        entry = tuple(e for e in entry if e not in used)
        if entry and dim % _axis_size(mesh, entry) == 0 and dim > 0:
            parts.append(entry if len(entry) > 1 else entry[0])
            used.update(entry)
        else:
            parts.append(None)
    return P(*parts)


def pshard(x: jax.Array, *entries) -> jax.Array:
    """Constrain with RAW mesh-axis names (not logical); entries may be None,
    an axis name, or a tuple of axis names.  Shape-aware like lshard: a
    non-dividing entry degrades to replication.  No-op without a mesh."""
    mesh = _CTX.mesh
    if mesh is None:
        return x
    parts = []
    used: set[str] = set()
    for dim, e in zip(x.shape, entries):
        names = tuple(a for a in ((e,) if isinstance(e, str) else (e or ()))
                      if a in mesh.axis_names and a not in used)
        if names and dim % _axis_size(mesh, names) == 0 and dim > 0:
            parts.append(names if len(names) > 1 else names[0])
            used.update(names)
        else:
            parts.append(None)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*parts)))


def lshard(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Constrain an activation's sharding by logical axis names (no-op when
    no mesh is active, e.g. single-device smoke tests)."""
    mesh, rules = _CTX.mesh, _CTX.rules
    if mesh is None:
        return x
    if len(logical) != x.ndim:
        raise ValueError(f"lshard: {len(logical)} names for rank-{x.ndim} array")
    spec = resolve_spec(logical, x.shape, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# Parameter sharding: path-based rules.
# ---------------------------------------------------------------------------

#: map from path-substring to logical dim names (matched in order; first hit
#: wins).  Paths are '/'-joined pytree key paths, e.g. "layers/attn/wq".
PARAM_RULES: list[tuple[str, tuple]] = [
    ("embed/tokens", ("vocab", None)),
    ("embed/pos", (None, None)),
    ("lm_head", (None, "vocab")),
    ("attn/wq", (None, "heads")),            # (d, H*hd) column-parallel
    ("attn/wk", (None, "kv_heads")),
    ("attn/wv", (None, "kv_heads")),
    ("attn/wo", ("heads", None)),            # row-parallel
    ("mlp/w_gate", (None, "ffn")),
    ("mlp/w_in", (None, "ffn")),
    ("mlp/w_out", ("ffn", None)),
    ("moe/router", (None, None)),
    ("moe/w_gate", ("experts", None, "expert_ff")),
    ("moe/w_in", ("experts", None, "expert_ff")),
    ("moe/w_out", ("experts", "expert_ff", None)),
    ("norm", (None,)),
    # xLSTM / Mamba2 projections: column-parallel in, row-parallel out
    ("ssm/w_in", (None, "ffn")),
    ("ssm/w_out", ("ffn", None)),
    ("ssm/", (None,)),                       # gates/biases: replicate
]


def _path_str(path) -> str:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
        else:
            out.append(str(k))
    return "/".join(out)


def logical_names_for(path_str: str, ndim: int) -> tuple:
    for frag, names in PARAM_RULES:
        if frag in path_str:
            if len(names) == ndim:
                return names
            if len(names) < ndim:
                # stacked-layer leading dim(s) from scan: pad on the left
                return (None,) * (ndim - len(names)) + tuple(names)
            return tuple(names[-ndim:]) if ndim else ()
    return (None,) * ndim


def param_spec(path_str: str, shape: Sequence[int], mesh: Mesh,
               rules: Optional[dict] = None) -> P:
    """TP/EP spec from the path rules, then ZeRO-3: the largest remaining
    replicated dim is sharded over the data axes (weights are all-gathered
    at use, gradients reduce-scattered -- the paper's DP volume v_d)."""
    rules = rules or default_rules(mesh.axis_names)
    base = resolve_spec(logical_names_for(path_str, len(shape)), shape, mesh, rules)
    fsdp_axes = tuple(rules.get("fsdp", ()) or ())
    if not fsdp_axes or "norm" in path_str or not shape:
        return base
    fsize = _axis_size(mesh, fsdp_axes)
    parts = list(base) + [None] * (len(shape) - len(base))
    for i in sorted(range(len(shape)), key=lambda i: -shape[i]):
        if parts[i] is None and shape[i] % fsize == 0 and shape[i] >= fsize:
            parts[i] = fsdp_axes if len(fsdp_axes) > 1 else fsdp_axes[0]
            return P(*parts)
    return base


def param_shardings(params_shape, mesh: Mesh, rules: Optional[dict] = None):
    """Pytree of NamedShardings for a params pytree (of arrays or
    ShapeDtypeStructs)."""
    rules = rules or default_rules(mesh.axis_names)

    def f(path, leaf):
        return NamedSharding(mesh, param_spec(_path_str(path), leaf.shape, mesh, rules))

    return jax.tree_util.tree_map_with_path(f, params_shape)


def opt_spec(path_str: str, shape: Sequence[int], mesh: Mesh,
             rules: Optional[dict] = None) -> P:
    """ZeRO-1: optimizer moments take the param spec, then shard the largest
    still-replicated dim over any data axes the param spec does not already
    use (with ZeRO-3/fsdp enabled, params usually consume them and the
    moments simply inherit that sharding)."""
    rules = rules or default_rules(mesh.axis_names)
    base = param_spec(path_str, shape, mesh, rules)
    used = {
        a
        for part in base
        if part
        for a in (part if isinstance(part, tuple) else (part,))
    }
    zero_axes = tuple(a for a in (rules.get("zero", ()) or ()) if a not in used)
    if not zero_axes:
        return base
    zsize = _axis_size(mesh, zero_axes)
    parts = list(base) + [None] * (len(shape) - len(base))
    order = sorted(range(len(shape)), key=lambda i: -shape[i])
    for i in order:
        if parts[i] is None and shape[i] % zsize == 0 and shape[i] > 0:
            parts[i] = zero_axes if len(zero_axes) > 1 else zero_axes[0]
            break
    return P(*parts)


def opt_shardings(params_shape, mesh: Mesh, rules: Optional[dict] = None):
    def f(path, leaf):
        return NamedSharding(mesh, opt_spec(_path_str(path), leaf.shape, mesh, rules))

    return jax.tree_util.tree_map_with_path(f, params_shape)
