from repro.parallel.sharding import (
    activate,
    active_mesh,
    default_rules,
    lshard,
    opt_shardings,
    param_shardings,
    param_spec,
    resolve_spec,
)

__all__ = [
    "activate", "active_mesh", "default_rules", "lshard", "opt_shardings",
    "param_shardings", "param_spec", "resolve_spec",
]
