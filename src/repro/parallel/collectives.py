"""Distributed-optimization collectives: gradient compression with error
feedback, and a compressed data-parallel mean built on shard_map/psum.

Beyond-paper feature (DESIGN.md §6): the DP gradient synchronization volume
``v_d`` -- the quantity Arnold's comm matrix tracks -- can be halved (fp16)
or quartered (int8) on the wire.  Error feedback keeps the compression
unbiased over time: the quantization residual is added back into the next
step's gradient, which preserves convergence (Karimireddy et al., 2019).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


# ------------------------------------------------------------- quantization
def quantize_fp16(g):
    return g.astype(jnp.float16)


def dequantize_fp16(q, _meta=None):
    return q.astype(jnp.float32)


def quantize_int8(g):
    """Symmetric per-tensor int8 with fp32 scale."""
    absmax = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12)
    scale = absmax / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


# ------------------------------------------------------------ error feedback
def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_with_feedback(grads, residuals, scheme: str = "fp16"):
    """Quantize (grads + carried residual); return (compressed-as-f32 grads,
    new residuals).  The returned grads are exactly what the receiving side
    would reconstruct, so optimizer math sees the true compressed values."""

    def one(g, r):
        x = g.astype(jnp.float32) + r
        if scheme == "fp16":
            q = quantize_fp16(x)
            deq = dequantize_fp16(q)
        elif scheme == "int8":
            q, s = quantize_int8(x)
            deq = dequantize_int8(q, s)
        else:
            raise ValueError(scheme)
        return deq, x - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residuals)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (
        jax.tree.unflatten(treedef, [o[0] for o in out]),
        jax.tree.unflatten(treedef, [o[1] for o in out]),
    )


# ------------------------------------------------- compressed DP all-reduce
def compressed_psum_mean(tree, axis_name: str, scheme: str = "fp16"):
    """psum-mean whose wire payload is quantized: each rank quantizes its
    local contribution, the sum runs over the narrow dtype (fp16) or the
    dequantized int8 values, and the mean is taken in fp32.  Called inside
    shard_map with a data-parallel axis."""
    n = jax.lax.psum(1, axis_name)

    def one(g):
        if scheme == "fp16":
            q = g.astype(jnp.float16)
            s = jax.lax.psum(q.astype(jnp.float32), axis_name)  # wire: fp16 payload
        elif scheme == "int8":
            q, scale = quantize_int8(g.astype(jnp.float32))
            s = jax.lax.psum(dequantize_int8(q, scale), axis_name)
        else:
            s = jax.lax.psum(g.astype(jnp.float32), axis_name)
        return s / n

    return jax.tree.map(one, tree)


def make_dp_grad_fn(loss_fn, mesh: Mesh, axis_name: str = "data",
                    scheme: str = "fp16"):
    """shard_map data-parallel value-and-grad with compressed gradient
    all-reduce: each shard computes grads on its micro-shard of the batch,
    then ``compressed_psum_mean`` synchronizes them."""
    from jax.experimental.shard_map import shard_map

    def local(params, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        grads = compressed_psum_mean(grads, axis_name, scheme)
        loss = jax.lax.pmean(loss, axis_name)
        return loss, grads

    batch_spec = P(axis_name)
    return shard_map(
        local,
        mesh=mesh,
        in_specs=(P(), jax.tree.map(lambda _: batch_spec, {"tokens": 0, "labels": 0})),
        out_specs=(P(), P()),
        check_rep=False,
    )
