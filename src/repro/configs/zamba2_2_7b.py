"""zamba2-2.7b: Mamba2 backbone + shared attention block every 6 layers.
[arXiv:2411.15242; hf]"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab=32000,
    ssm_state=64,
    ssm_heads=80,           # d_inner=5120, mamba2 head_dim=64
    ssm_expand=2,
    attn_every=6,           # one shared attention block applied every 6
    head_dim=80,
    long_context_window=4096,  # sliding-window cap for long_500k decode
    notes="Mamba2 + shared attn; O(1)/windowed decode state -> long_500k runs",
)
