"""dbrx-132b: Databricks DBRX -- fine-grained MoE, 16 experts top-4.
[hf:databricks/dbrx-base; unverified]"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10752,
    vocab=100352,
    n_experts=16,
    top_k=4,
    d_expert=10752,
    head_dim=128,
    notes="16 experts top-4, fine-grained MoE",
)
