"""granite-8b: IBM Granite 8B (code) -- llama-arch dense transformer.
[arXiv:2405.04324; hf]"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,           # GQA
    d_ff=14336,
    vocab=49152,
    head_dim=128,
    rope_theta=10_000_000.0,
    notes="llama-arch, code model; RoPE + SwiGLU + GQA",
)
