"""xlstm-350m: xLSTM with sLSTM + mLSTM blocks (ratio 3:1).
[arXiv:2405.04517; unverified]"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,                 # xLSTM blocks own their projections
    vocab=50304,
    head_dim=256,
    slstm_every=4,          # repeating unit [mLSTM x3, sLSTM x1]
    ssm_expand=2,
    notes="sLSTM + mLSTM blocks; recurrent O(1) decode state -> long_500k runs",
)
