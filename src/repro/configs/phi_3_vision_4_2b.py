"""phi-3-vision-4.2b: Phi-3-mini backbone + CLIP ViT frontend (STUB).
The modality frontend is a stub: input_specs() provides precomputed patch
embeddings (b, n_patches, d_model).  [hf:microsoft/Phi-3-vision-128k-instruct; hf]"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,          # MHA
    d_ff=8192,
    vocab=32064,
    head_dim=96,
    n_patches=576,          # CLIP-L/14 @ 336px visual prefix
    notes="phi3-mini + CLIP; patch embeds are a stub input",
)
