"""whisper-tiny: encoder-decoder ASR; conv frontend is a STUB (input_specs()
provides precomputed frame embeddings).  [arXiv:2212.04356; unverified]"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,             # decoder layers
    n_encoder_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab=51865,
    head_dim=64,
    notes="enc-dec; conv frontend stubbed as precomputed frame embeddings",
)
