"""Assigned-architecture registry: ``get_config(name)`` / ``--arch <id>``."""

from repro.configs.base import SHAPES, ArchConfig, ShapeSpec
from repro.configs.dbrx_132b import CONFIG as DBRX_132B
from repro.configs.glm4_9b import CONFIG as GLM4_9B
from repro.configs.granite_8b import CONFIG as GRANITE_8B
from repro.configs.minicpm_2b import CONFIG as MINICPM_2B
from repro.configs.phi4_mini_3_8b import CONFIG as PHI4_MINI_3_8B
from repro.configs.phi_3_vision_4_2b import CONFIG as PHI_3_VISION_4_2B
from repro.configs.qwen3_moe_235b_a22b import CONFIG as QWEN3_MOE_235B_A22B
from repro.configs.whisper_tiny import CONFIG as WHISPER_TINY
from repro.configs.xlstm_350m import CONFIG as XLSTM_350M
from repro.configs.zamba2_2_7b import CONFIG as ZAMBA2_2_7B

ARCHS: dict[str, ArchConfig] = {
    c.name: c
    for c in [
        GRANITE_8B,
        MINICPM_2B,
        GLM4_9B,
        PHI4_MINI_3_8B,
        DBRX_132B,
        QWEN3_MOE_235B_A22B,
        PHI_3_VISION_4_2B,
        XLSTM_350M,
        WHISPER_TINY,
        ZAMBA2_2_7B,
    ]
}


def get_config(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


__all__ = ["ARCHS", "ArchConfig", "SHAPES", "ShapeSpec", "get_config"]
