"""qwen3-moe-235b-a22b: Qwen3 MoE 235B (22B active) -- 128 experts top-8.
[hf:Qwen/Qwen3-30B-A3B; hf]"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    d_ff=1536,              # per-expert FFN hidden
    vocab=151936,
    n_experts=128,
    top_k=8,
    d_expert=1536,
    head_dim=128,
    notes="128 experts top-8; deepest assigned arch (94L)",
)
