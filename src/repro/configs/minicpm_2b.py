"""minicpm-2b: MiniCPM 2.4B -- llama-like dense, WSD schedule, tied embeddings.
[arXiv:2404.06395; hf]"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="minicpm-2b",
    family="dense",
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv_heads=36,          # MHA (kv=36)
    d_ff=5760,
    vocab=122753,
    head_dim=64,
    tie_embeddings=True,
    lr_schedule="wsd",      # Warmup-Stable-Decay (the paper's contribution)
    notes="WSD schedule (arch=llama-like)",
)
