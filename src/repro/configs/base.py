"""Architecture configuration schema + input-shape registry.

Every assigned architecture gets one ``src/repro/configs/<id>.py`` exporting
``CONFIG`` (the exact published hyper-parameters) built on :class:`ArchConfig`.
``reduced()`` derives the CPU-smoke variant (same family/topology, tiny
widths).  The input-shape set is shared by all LM-family archs:

    train_4k     seq 4096  x global batch 256   (train_step)
    prefill_32k  seq 32768 x global batch 32    (serve prefill)
    decode_32k   seq 32768 KV x global batch 128 (serve_step, 1 new token)
    long_500k    seq 524288 KV x global batch 1  (serve_step; sub-quadratic
                                                  archs only -- see DESIGN.md)
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """One architecture; covers dense / MoE / VLM / SSM / audio / hybrid."""

    name: str
    family: str               # dense | moe | vlm | ssm | audio | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    # MoE
    n_experts: int = 0
    top_k: int = 0
    d_expert: int = 0         # per-expert FFN hidden (0 -> use d_ff)
    capacity_factor: float = 1.25

    # SSM / hybrid
    ssm_state: int = 0
    ssm_heads: int = 0        # mamba2 heads (0 -> derived)
    ssm_expand: int = 2
    attn_every: int = 0       # hybrid: shared attention every k blocks
    slstm_every: int = 0      # xlstm: one sLSTM per k-block repeating unit
    long_context_window: int = 0  # sliding-window cap for hybrid attention

    # audio (enc-dec)
    n_encoder_layers: int = 0

    # common
    head_dim: int = 0         # 0 -> d_model // n_heads
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"

    # modality frontends are STUBS: input_specs() provides precomputed
    # patch/frame embeddings (see DESIGN.md §4)
    n_patches: int = 0        # vlm: visual prefix length

    # schedule hint (minicpm uses WSD)
    lr_schedule: str = "cosine"

    notes: str = ""

    def __post_init__(self):
        if self.n_heads % max(self.n_kv_heads, 1):
            raise ValueError(f"{self.name}: n_heads must be divisible by n_kv_heads")

    # ------------------------------------------------------------ derived
    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so the vocab-parallel axis
        divides any power-of-two TP degree (a production necessity: an
        unpadded 122753-entry table replicates the (b,s,V) logits on the
        model axis -- +15 GiB/device for minicpm train_4k)."""
        return ((self.vocab + 255) // 256) * 256

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def expert_ff(self) -> int:
        return self.d_expert or self.d_ff

    @property
    def supports_decode(self) -> bool:
        return True  # all assigned archs are (or contain) decoders

    @property
    def supports_long_context(self) -> bool:
        # long_500k runs only for archs whose per-token decode state is O(1)
        # or window-bounded in sequence length (SSM / hybrid).
        return self.family in ("ssm", "hybrid")

    def shapes(self) -> list[ShapeSpec]:
        out = [SHAPES["train_4k"], SHAPES["prefill_32k"], SHAPES["decode_32k"]]
        if self.supports_long_context:
            out.append(SHAPES["long_500k"])
        return out

    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS in roofline)."""
        d, hd = self.d_model, self.resolved_head_dim
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) + (self.n_heads * hd) * d
        if self.is_moe:
            ffn = 3 * d * self.expert_ff * self.n_experts + d * self.n_experts  # + router
        elif self.d_ff:
            ffn = 3 * d * self.d_ff
        else:
            ffn = 0
        per_layer = attn + ffn + 2 * d
        if self.family == "ssm":
            d_in = self.ssm_expand * d
            per_layer = 2 * d * d_in + d_in * d + 4 * d  # qkv-ish proj + gates
        if self.family == "hybrid":
            d_in = self.ssm_expand * d
            per_layer = d * (2 * d_in + 2 * self.ssm_state * 2) + d_in * d
        total = emb + self.n_layers * per_layer
        if self.family == "audio":
            total += self.n_encoder_layers * per_layer
        if self.family == "hybrid" and self.attn_every:
            total += 4 * (2 * d) * (2 * d)  # shared attention block (2d wide)
        return int(total)

    def active_param_count(self) -> int:
        """Activated params per token (MoE: top_k of n_experts)."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        dense = self.param_count() - 3 * d * self.expert_ff * self.n_experts * self.n_layers
        return int(dense + 3 * d * self.expert_ff * self.top_k * self.n_layers)

    # ------------------------------------------------------------- reduced
    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        scale = {
            "d_model": 64,
            "n_layers": max(2, min(4, self.n_layers)),
            "n_heads": 4,
            "n_kv_heads": max(1, min(4, self.n_kv_heads if self.n_kv_heads < self.n_heads else 4)),
            "d_ff": 128 if self.d_ff else 0,
            "vocab": 512,
            "head_dim": 16,
        }
        if self.is_moe:
            scale.update(n_experts=4, top_k=min(2, self.top_k), d_expert=64)
        if self.ssm_state:
            scale.update(ssm_state=16, ssm_heads=4)
        if self.attn_every:
            scale.update(attn_every=2)
        if self.slstm_every:
            scale.update(slstm_every=2)
        if self.n_encoder_layers:
            scale.update(n_encoder_layers=2)
        if self.n_patches:
            scale.update(n_patches=16)
        if self.long_context_window:
            scale.update(long_context_window=64)
        return dataclasses.replace(self, **scale)
