"""Sharded checkpointing without orbax (offline container): one ``.npy`` per
pytree leaf + a JSON manifest, atomic directory rename, optional async save
thread, keep-last-N retention, and restore with target shardings.

This is the persistence layer behind the trainer's fault tolerance: saves
are atomic (a crash mid-save never corrupts the latest checkpoint) and
``latest_step`` + deterministic data (data/pipeline.py) make restart exact.
"""

from __future__ import annotations

import json
import pathlib
import re
import shutil
import threading

import jax
import ml_dtypes
import numpy as np

_STEP_RE = re.compile(r"^step_(\d+)$")

#: numpy cannot round-trip ml_dtypes through .npy; store as byte views.
_VIEW_AS = {
    "bfloat16": np.uint16,
    "float8_e4m3fn": np.uint8,
    "float8_e5m2": np.uint8,
}


def _encode(arr: np.ndarray) -> tuple[np.ndarray, str]:
    name = str(arr.dtype)
    if name in _VIEW_AS:
        return arr.view(_VIEW_AS[name]), name
    return arr, name


def _decode(arr: np.ndarray, name: str) -> np.ndarray:
    if name in _VIEW_AS:
        return arr.view(np.dtype(getattr(ml_dtypes, name)))
    return arr


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    items = []
    for path, leaf in flat:
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path
        )
        items.append((key, leaf))
    return items, treedef


class Checkpointer:
    def __init__(self, directory, keep_last: int = 3, use_async: bool = False):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep_last = keep_last
        self.use_async = use_async
        self._pending: threading.Thread | None = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree) -> pathlib.Path:
        """Atomic save; with use_async=True returns immediately after
        snapshotting to host memory."""
        items, _ = _flatten(tree)
        host = [(k, np.asarray(v)) for k, v in items]
        if self.use_async:
            self.wait()
            self._pending = threading.Thread(
                target=self._write, args=(step, host), daemon=True
            )
            self._pending.start()
        else:
            self._write(step, host)
        return self.dir / f"step_{step}"

    def _write(self, step: int, host_items):
        tmp = self.dir / f".tmp_step_{step}"
        final = self.dir / f"step_{step}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {}
        for i, (key, arr) in enumerate(host_items):
            fname = f"leaf_{i:05d}.npy"
            raw, dtype_name = _encode(arr)
            np.save(tmp / fname, raw)
            manifest[key] = {
                "file": fname,
                "shape": list(arr.shape),
                "dtype": dtype_name,
            }
        (tmp / "manifest.json").write_text(
            json.dumps({"step": step, "leaves": manifest})
        )
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)  # atomic on POSIX
        self._gc()

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self):
        steps = sorted(self.steps())
        for s in steps[: max(0, len(steps) - self.keep_last)]:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    # --------------------------------------------------------------- restore
    def steps(self) -> list[int]:
        out = []
        for p in self.dir.iterdir():
            m = _STEP_RE.match(p.name)
            if m and (p / "manifest.json").exists():
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.steps()
        return steps[-1] if steps else None

    def restore(self, template, step: int | None = None, shardings=None):
        """Restore into the structure of ``template`` (params/opt pytree of
        arrays or ShapeDtypeStructs).  ``shardings``: matching pytree of
        NamedShardings for sharded device placement."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        path = self.dir / f"step_{step}"
        manifest = json.loads((path / "manifest.json").read_text())["leaves"]
        items, treedef = _flatten(template)
        shard_items = None
        if shardings is not None:
            shard_items, _ = _flatten(shardings)
        leaves = []
        for i, (key, tmpl) in enumerate(items):
            if key not in manifest:
                raise KeyError(f"checkpoint missing leaf {key!r}")
            arr = _decode(np.load(path / manifest[key]["file"]), manifest[key]["dtype"])
            if tuple(arr.shape) != tuple(tmpl.shape):
                raise ValueError(
                    f"{key}: ckpt shape {arr.shape} != template {tmpl.shape}"
                )
            if shard_items is not None:
                arr = jax.device_put(arr, shard_items[i][1])
            else:
                arr = jax.numpy.asarray(arr, dtype=tmpl.dtype)
            leaves.append(arr)
        return jax.tree_util.tree_unflatten(treedef, leaves)
