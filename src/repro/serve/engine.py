"""Continuous-batching generation engine (DESIGN.md §7.2).

One engine drives one model replica.  It keeps a fixed set of ``max_batch``
*lanes*; every decode step runs all lanes through one jitted
``decode_step_paged`` call (inactive lanes masked), so requests join and
leave the batch mid-flight with no recompilation:

* **admission** -- a request is admitted when a lane is free *and* its
  worst-case page demand (``ceil((prompt + max_new) / page_size)``) fits in
  the uncommitted page pool.  Pages are committed logically at admission but
  allocated physically on demand (prefill pages up front, one page whenever
  decode crosses a page boundary), so the free list can never run dry
  mid-flight -- the deadlock-free variant of vLLM-style paging.
* **prefill** -- each admitted prompt runs one ``prefill_paged`` call,
  padded to a power-of-two bucket to bound jit retraces; its KV is scattered
  straight into the lane's pages and the first output token is sampled from
  the last prompt position.
* **decode** -- one batched greedy step per tick over every active lane,
  each lane at its own length (per-lane RoPE positions and masks).
* **eviction** -- a lane finishing (length budget or EOS) releases its pages
  back to the free list the same tick, and the lane is immediately
  re-admittable.

Per-lane computation is independent of batch composition, so the engine
produces token-for-token the same output as one-at-a-time dense decode --
the equivalence property tests pin down, and what makes seeded load-gen
runs reproducible even though batching is timing-dependent.

Token accounting: the engine clock starts *after* jit warm-up
(:meth:`ServeEngine.run` warms the decode step and every prefill bucket it
will need), and every counted token is timestamped inside the measured
window -- fixing the warm-up-token bug of the old fixed-batch demo.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve.kv_cache import PagedCacheConfig, PagedKVCache
from repro.serve.request import GenerationRequest, GenerationResult


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Capacity knobs; defaults suit CPU smoke runs of reduced configs."""

    max_batch: int = 8          # lanes = max concurrent sequences
    page_size: int = 16         # tokens per KV page
    n_pages: int = 96           # shared page pool (all lanes, per layer)
    max_blocks: int = 8         # block-table length; max ctx = blocks * page
    min_prefill_bucket: int = 8

    def cache_config(self) -> PagedCacheConfig:
        return PagedCacheConfig(
            n_pages=self.n_pages, page_size=self.page_size,
            max_batch=self.max_batch, max_blocks=self.max_blocks,
        )

    def prefill_bucket(self, n: int) -> int:
        """Smallest power-of-two bucket >= n (bounds jit retraces)."""
        b = self.min_prefill_bucket
        while b < n:
            b *= 2
        return b


@dataclasses.dataclass
class EngineStats:
    """Counters over the measured window (clock starts after warm-up)."""

    decode_steps: int = 0
    prefills: int = 0
    tokens_generated: int = 0   # every token timestamped inside the window
    elapsed_s: float = 0.0
    occupancy: list[int] = dataclasses.field(default_factory=list)
    peak_pages_in_use: int = 0

    @property
    def tokens_per_s(self) -> float:
        return self.tokens_generated / self.elapsed_s if self.elapsed_s else 0.0

    @property
    def mean_occupancy(self) -> float:
        return float(np.mean(self.occupancy)) if self.occupancy else 0.0


@dataclasses.dataclass
class _Lane:
    request: GenerationRequest
    admitted_s: float
    length: int                 # tokens materialized in the KV cache
    last_token: int             # fed to the next decode step
    tokens: list[int]
    token_times: list[float]
    committed_blocks: int


class ServeEngine:
    """Continuous-batching engine over one ``DecoderLM`` replica."""

    def __init__(self, model, params, config: EngineConfig | None = None):
        self.model = model
        self.params = params
        self.config = config or EngineConfig()
        self.cache = PagedKVCache(model, self.config.cache_config())
        self._lanes: list[Optional[_Lane]] = [None] * self.config.max_batch
        self._pending: deque[GenerationRequest] = deque()  # future arrivals
        self._waiting: deque[GenerationRequest] = deque()  # arrived, unadmitted
        self._committed_blocks = 0
        self._t0: Optional[float] = None
        self.stats = EngineStats()
        self.results: list[GenerationResult] = []

        def decode_fn(params, pages, tables, lengths, tokens, active):
            logits, pages = model.decode_step_paged(
                params, pages, tables, lengths, tokens, active
            )
            return jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32), pages

        def prefill_fn(params, pages, table, length, tokens):
            logits, pages = model.prefill_paged(params, pages, table, length, tokens)
            last = jnp.take(logits[0], length - 1, axis=0)
            return jnp.argmax(last).astype(jnp.int32), pages

        self._decode = jax.jit(decode_fn, donate_argnums=(1,))
        self._prefill = jax.jit(prefill_fn, donate_argnums=(1,))

    # ------------------------------------------------------------------ clock
    def now(self) -> float:
        if self._t0 is None:
            raise RuntimeError("clock not started (call run())")
        return time.perf_counter() - self._t0

    # -------------------------------------------------------------- admission
    def submit(self, request: GenerationRequest) -> None:
        cap = self.cache.config.max_context
        if request.worst_case_tokens > cap:
            raise ValueError(
                f"request {request.request_id}: prompt + max_new = "
                f"{request.worst_case_tokens} exceeds max context {cap}"
            )
        need = self.cache.config.blocks_for(request.worst_case_tokens)
        if need > self.config.n_pages:
            raise ValueError(
                f"request {request.request_id}: needs {need} pages, pool has "
                f"{self.config.n_pages} -- it could never be admitted"
            )
        self._pending.append(request)

    def _free_lane(self) -> Optional[int]:
        for i, lane in enumerate(self._lanes):
            if lane is None:
                return i
        return None

    def _can_admit(self, request: GenerationRequest) -> bool:
        need = self.cache.config.blocks_for(request.worst_case_tokens)
        return self._committed_blocks + need <= self.config.n_pages

    def _admit(self, request: GenerationRequest, lane_id: int) -> None:
        """Grant a lane + page commitment, then prefill the prompt."""
        cfg = self.config
        prompt = list(request.prompt)
        admitted = self.now()
        need = self.cache.config.blocks_for(request.worst_case_tokens)
        self._committed_blocks += need
        self.cache.ensure_capacity(lane_id, len(prompt))

        bucket = cfg.prefill_bucket(len(prompt))
        padded = np.zeros((1, bucket), np.int32)
        padded[0, : len(prompt)] = prompt
        first, self.cache.pages = self._prefill(
            self.params, self.cache.pages, self.cache.lane_table(lane_id),
            jnp.int32(len(prompt)), jnp.asarray(padded),
        )
        first = int(jax.block_until_ready(first))
        t = self.now()
        self.stats.prefills += 1
        self.stats.tokens_generated += 1
        lane = _Lane(
            request=request, admitted_s=admitted, length=len(prompt),
            last_token=first, tokens=[first], token_times=[t],
            committed_blocks=need,
        )
        self._lanes[lane_id] = lane
        if self._is_finished(lane, first):
            self._finish(lane_id, t, reason=self._reason(lane, first))

    def _admit_arrivals(self) -> None:
        now = self.now()
        while self._pending and self._pending[0].arrival_s <= now:
            self._waiting.append(self._pending.popleft())
        while self._waiting:
            lane_id = self._free_lane()
            if lane_id is None or not self._can_admit(self._waiting[0]):
                break
            self._admit(self._waiting.popleft(), lane_id)

    # ----------------------------------------------------------------- decode
    @staticmethod
    def _is_finished(lane: _Lane, token: int) -> bool:
        req = lane.request
        return len(lane.tokens) >= req.max_new_tokens or token == req.eos_id

    @staticmethod
    def _reason(lane: _Lane, token: int) -> str:
        return "eos" if token == lane.request.eos_id else "length"

    def _finish(self, lane_id: int, t: float, reason: str) -> None:
        lane = self._lanes[lane_id]
        self.cache.release(lane_id)
        self._committed_blocks -= lane.committed_blocks
        self._lanes[lane_id] = None
        self.results.append(GenerationResult(
            request_id=lane.request.request_id, prompt=lane.request.prompt,
            tokens=lane.tokens, arrival_s=lane.request.arrival_s,
            admitted_s=lane.admitted_s, finished_s=t,
            token_times_s=lane.token_times, finish_reason=reason,
        ))

    def _decode_tick(self) -> None:
        active_ids = [i for i, l in enumerate(self._lanes) if l is not None]
        if not active_ids:
            return
        nb = self.config.max_batch
        tokens = np.zeros((nb, 1), np.int32)
        lengths = np.zeros(nb, np.int32)
        active = np.zeros(nb, bool)
        for i in active_ids:
            lane = self._lanes[i]
            # the incoming token is written at position `length`
            self.cache.ensure_capacity(i, lane.length + 1)
            tokens[i, 0] = lane.last_token
            lengths[i] = lane.length
            active[i] = True
        out, self.cache.pages = self._decode(
            self.params, self.cache.pages, self.cache.device_block_tables(),
            jnp.asarray(lengths), jnp.asarray(tokens), jnp.asarray(active),
        )
        out = np.asarray(jax.block_until_ready(out))
        t = self.now()
        self.stats.decode_steps += 1
        self.stats.occupancy.append(len(active_ids))
        self.stats.peak_pages_in_use = max(
            self.stats.peak_pages_in_use, self.cache.allocator.n_allocated
        )
        for i in active_ids:
            lane = self._lanes[i]
            token = int(out[i])
            lane.length += 1
            lane.last_token = token
            lane.tokens.append(token)
            lane.token_times.append(t)
            self.stats.tokens_generated += 1
            if self._is_finished(lane, token):
                self._finish(i, t, reason=self._reason(lane, token))

    # -------------------------------------------------------------------- run
    def _warmup(self, requests: list[GenerationRequest]) -> None:
        """Compile the decode step and every prefill bucket outside the
        measured window (none of this is counted or timestamped)."""
        nb = self.config.max_batch
        _, self.cache.pages = self._decode(
            self.params, self.cache.pages, self.cache.device_block_tables(),
            jnp.zeros(nb, jnp.int32), jnp.zeros((nb, 1), jnp.int32),
            jnp.zeros(nb, bool),
        )
        empty = jnp.full((self.config.max_blocks,), -1, jnp.int32)
        for bucket in sorted({self.config.prefill_bucket(len(r.prompt))
                              for r in requests}):
            _, self.cache.pages = self._prefill(
                self.params, self.cache.pages, empty, jnp.int32(1),
                jnp.zeros((1, bucket), jnp.int32),
            )
        jax.block_until_ready(self.cache.pages)

    def run(self, requests: list[GenerationRequest] | None = None,
            ) -> tuple[list[GenerationResult], EngineStats]:
        """Serve ``requests`` (plus anything already submitted) to
        completion; returns (results, stats) and leaves every page free."""
        for r in requests or []:
            self.submit(r)
        queued = sorted(self._pending, key=lambda r: (r.arrival_s, r.request_id))
        self._pending = deque(queued)
        self._warmup(queued)

        self._t0 = time.perf_counter()
        while self._pending or self._waiting or any(self._lanes):
            self._admit_arrivals()
            if any(self._lanes):
                self._decode_tick()
            elif self._pending:
                # idle until the next arrival (nothing to batch)
                wait = self._pending[0].arrival_s - self.now()
                if wait > 0:
                    time.sleep(min(wait, 0.01))
        self.stats.elapsed_s = self.now()
        self.results.sort(key=lambda r: r.request_id)
        return self.results, self.stats
