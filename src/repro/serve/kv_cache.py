"""Paged/blocked KV cache bookkeeping (DESIGN.md §7.1).

The physical KV store is a pool of fixed-size *pages* shared by every
sequence -- per layer ``{"k","v"}: (n_pages, page_size, K, hd)`` device
arrays owned by :class:`PagedKVCache` -- and each lane (batch slot) owns an
ordered *block table* of page ids.  Logical token position ``p`` of a lane
lives at physical slot ``table[p // page_size] * page_size + p % page_size``.

This module is pure host-side bookkeeping (numpy block tables + a free-list
allocator); the device-side scatter/gather compute is
:func:`repro.models.layers.attention_decode_paged` /
:func:`attention_prefill_paged`, driven by the engine.

Invariants the tests pin down:

* a page is either on the free list or owned by exactly one lane;
* double-free and foreign-page frees raise;
* after every sequence of a trace is released the allocator is fully free
  (no leaked pages).
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np


class OutOfPages(RuntimeError):
    """The free list is empty (admission control should prevent this)."""


class PageAllocator:
    """LIFO free-list over ``n_pages`` page ids with ownership checks."""

    def __init__(self, n_pages: int):
        if n_pages <= 0:
            raise ValueError(f"n_pages must be positive, got {n_pages}")
        self.n_pages = n_pages
        self._free: list[int] = list(range(n_pages - 1, -1, -1))
        self._owner: dict[int, int] = {}  # page id -> lane

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_allocated(self) -> int:
        return self.n_pages - len(self._free)

    def alloc(self, owner: int) -> int:
        if not self._free:
            raise OutOfPages(f"all {self.n_pages} pages allocated")
        page = self._free.pop()
        self._owner[page] = owner
        return page

    def free(self, page: int, owner: int) -> None:
        if page not in self._owner:
            raise ValueError(f"page {page} is not allocated (double free?)")
        if self._owner[page] != owner:
            raise ValueError(
                f"page {page} owned by lane {self._owner[page]}, "
                f"freed by lane {owner}"
            )
        del self._owner[page]
        self._free.append(page)

    def pages_of(self, owner: int) -> list[int]:
        return sorted(p for p, o in self._owner.items() if o == owner)

    def assert_all_free(self) -> None:
        if self._owner:
            raise AssertionError(f"leaked pages: {sorted(self._owner)}")


@dataclasses.dataclass(frozen=True)
class PagedCacheConfig:
    n_pages: int
    page_size: int
    max_batch: int          # number of lanes
    max_blocks: int         # block-table length = max context / page_size

    @property
    def max_context(self) -> int:
        return self.max_blocks * self.page_size

    def blocks_for(self, n_tokens: int) -> int:
        """Pages needed to hold ``n_tokens`` (worst case for admission)."""
        return -(-n_tokens // self.page_size)


class PagedKVCache:
    """Device page pool + host block tables for up to ``max_batch`` lanes.

    ``pages`` is the model's per-layer pytree from
    :meth:`DecoderLM.init_paged_cache`; the engine threads it functionally
    through the jitted decode/prefill steps and assigns it back here.
    ``block_tables`` is a (max_batch, max_blocks) int32 array, -1 meaning
    unallocated, handed to the device step each call (a few hundred bytes).
    """

    def __init__(self, model, config: PagedCacheConfig):
        self.config = config
        self.allocator = PageAllocator(config.n_pages)
        self.pages = model.init_paged_cache(config.n_pages, config.page_size)
        self.block_tables = np.full(
            (config.max_batch, config.max_blocks), -1, np.int32
        )
        self._n_blocks = np.zeros(config.max_batch, np.int32)

    # ------------------------------------------------------------- capacity
    def ensure_capacity(self, lane: int, n_tokens: int) -> None:
        """Grow lane's block table so positions ``[0, n_tokens)`` are backed
        by pages, allocating from the free list as needed."""
        cfg = self.config
        if n_tokens > cfg.max_context:
            raise ValueError(
                f"{n_tokens} tokens exceed max context {cfg.max_context}"
            )
        need = cfg.blocks_for(n_tokens)
        while self._n_blocks[lane] < need:
            page = self.allocator.alloc(lane)
            self.block_tables[lane, self._n_blocks[lane]] = page
            self._n_blocks[lane] += 1

    def release(self, lane: int) -> None:
        """Return all of lane's pages to the free list (page *recycling*;
        the stale KV values in them are dead -- any future owner overwrites
        slots before its masks expose them)."""
        for i in range(int(self._n_blocks[lane])):
            self.allocator.free(int(self.block_tables[lane, i]), lane)
        self.block_tables[lane, :] = -1
        self._n_blocks[lane] = 0

    def n_blocks(self, lane: int) -> int:
        return int(self._n_blocks[lane])

    # ---------------------------------------------------------- device views
    def device_block_tables(self) -> jax.Array:
        import jax.numpy as jnp

        return jnp.asarray(self.block_tables)

    def lane_table(self, lane: int) -> jax.Array:
        import jax.numpy as jnp

        return jnp.asarray(self.block_tables[lane])
