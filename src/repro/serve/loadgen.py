"""Seeded load generator + serving benchmark report (DESIGN.md §7.3).

Workloads are fully determined by a :class:`LoadGenConfig` seed: request
arrivals are a Poisson process (exponential inter-arrival gaps at
``rate_rps``), and prompt/response lengths are drawn from discrete
*mixtures* (the short-chat / long-doc mixes real serving traces show).
Because the engine's output is batching-invariant, the *tokens* of a seeded
run are reproducible across machines; only the wall-clock latencies differ.

:func:`run_benchmark` drives an engine over a generated workload and
distills a :class:`ServeReport`: tokens/sec over the measured window,
goodput (completed-request tokens/sec), TTFT and per-token p50/p99, e2e
latency, and batch occupancy -- the cross-PR perf surface
``benchmarks/bench_serve.py`` snapshots into ``BENCH_serve.json``.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.serve.engine import EngineStats, ServeEngine
from repro.serve.request import GenerationRequest, GenerationResult


@dataclasses.dataclass(frozen=True)
class LengthMixture:
    """Discrete length distribution: ((length, weight), ...)."""

    components: tuple[tuple[int, float], ...]

    def __post_init__(self):
        if not self.components:
            raise ValueError("mixture needs at least one component")
        if any(n < 1 or w < 0 for n, w in self.components):
            raise ValueError(f"bad mixture {self.components}")

    def sample(self, rng: np.random.Generator, size: int) -> np.ndarray:
        lengths = np.array([n for n, _ in self.components])
        w = np.array([w for _, w in self.components], dtype=float)
        return rng.choice(lengths, size=size, p=w / w.sum())

    @property
    def max_length(self) -> int:
        return max(n for n, _ in self.components)


# short-chat-heavy defaults, scaled for CPU-sized reduced configs
DEFAULT_PROMPT_MIX = LengthMixture(((4, 0.5), (8, 0.3), (16, 0.2)))
DEFAULT_RESPONSE_MIX = LengthMixture(((8, 0.5), (16, 0.35), (32, 0.15)))


@dataclasses.dataclass(frozen=True)
class LoadGenConfig:
    seed: int = 0
    n_requests: int = 16
    rate_rps: float = 50.0          # Poisson arrival rate
    prompt_mix: LengthMixture = DEFAULT_PROMPT_MIX
    response_mix: LengthMixture = DEFAULT_RESPONSE_MIX
    vocab: int = 512                # prompt tokens drawn uniformly from here
    eos_id: int | None = None

    @property
    def worst_case_tokens(self) -> int:
        return self.prompt_mix.max_length + self.response_mix.max_length


def generate_requests(cfg: LoadGenConfig) -> list[GenerationRequest]:
    """Seeded Poisson workload; same seed -> identical request list."""
    rng = np.random.default_rng(cfg.seed)
    gaps = rng.exponential(1.0 / cfg.rate_rps, size=cfg.n_requests)
    arrivals = np.cumsum(gaps)
    prompt_lens = cfg.prompt_mix.sample(rng, cfg.n_requests)
    response_lens = cfg.response_mix.sample(rng, cfg.n_requests)
    requests = []
    for i in range(cfg.n_requests):
        prompt = rng.integers(0, cfg.vocab, size=int(prompt_lens[i]))
        requests.append(GenerationRequest(
            request_id=i,
            prompt=tuple(int(t) for t in prompt),
            max_new_tokens=int(response_lens[i]),
            arrival_s=float(arrivals[i]),
            eos_id=cfg.eos_id,
        ))
    return requests


def _pct(values, q) -> float:
    return float(np.percentile(np.asarray(values), q)) if len(values) else 0.0


@dataclasses.dataclass
class ServeReport:
    """Latency/throughput summary of one load-gen run."""

    n_requests: int
    n_completed: int
    total_tokens: int               # generated inside the measured window
    elapsed_s: float
    tokens_per_s: float
    goodput_tokens_per_s: float     # tokens of *completed* requests only
    ttft_p50_ms: float
    ttft_p99_ms: float
    per_token_p50_ms: float         # inter-token (decode cadence)
    per_token_p99_ms: float
    e2e_p50_ms: float
    e2e_p99_ms: float
    mean_batch_occupancy: float
    peak_pages_in_use: int

    @classmethod
    def from_run(cls, results: list[GenerationResult], stats: EngineStats
                 ) -> "ServeReport":
        ttft = [r.ttft_s * 1e3 for r in results]
        gaps = [g * 1e3 for r in results for g in r.inter_token_s()]
        e2e = [r.e2e_s * 1e3 for r in results]
        completed_tokens = sum(r.n_generated for r in results)
        elapsed = stats.elapsed_s
        return cls(
            n_requests=len(results),
            n_completed=len(results),
            total_tokens=stats.tokens_generated,
            elapsed_s=elapsed,
            tokens_per_s=stats.tokens_per_s,
            goodput_tokens_per_s=completed_tokens / elapsed if elapsed else 0.0,
            ttft_p50_ms=_pct(ttft, 50), ttft_p99_ms=_pct(ttft, 99),
            per_token_p50_ms=_pct(gaps, 50), per_token_p99_ms=_pct(gaps, 99),
            e2e_p50_ms=_pct(e2e, 50), e2e_p99_ms=_pct(e2e, 99),
            mean_batch_occupancy=stats.mean_occupancy,
            peak_pages_in_use=stats.peak_pages_in_use,
        )

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def summary(self) -> str:
        return (
            f"{self.n_completed}/{self.n_requests} requests, "
            f"{self.total_tokens} tokens in {self.elapsed_s:.2f}s "
            f"({self.tokens_per_s:.0f} tok/s, goodput "
            f"{self.goodput_tokens_per_s:.0f} tok/s)\n"
            f"TTFT p50/p99 {self.ttft_p50_ms:.1f}/{self.ttft_p99_ms:.1f} ms; "
            f"per-token p50/p99 {self.per_token_p50_ms:.1f}/"
            f"{self.per_token_p99_ms:.1f} ms; "
            f"e2e p50/p99 {self.e2e_p50_ms:.0f}/{self.e2e_p99_ms:.0f} ms\n"
            f"mean batch occupancy {self.mean_batch_occupancy:.2f}, "
            f"peak pages in use {self.peak_pages_in_use}"
        )


def run_benchmark(engine: ServeEngine, requests: list[GenerationRequest]
                  ) -> ServeReport:
    """Drive ``engine`` through ``requests`` and summarize."""
    results, stats = engine.run(requests)
    return ServeReport.from_run(results, stats)
