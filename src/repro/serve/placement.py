"""Topology-aware serving-replica placement (DESIGN.md §7.4).

Serving replicas are "just another communication-group workload" (survey
arXiv:2407.20018): a replica is a small TP/PP job whose comm matrix flows
through the same unified :mod:`repro.core.scheduler` registry as training
jobs, so serving traffic exercises the topology-aware placement path --
including :class:`FallbackChain` degradation -- with zero scheduler changes.

Replicas are placed sequentially: each replica's nodes are allocated before
the next solve, so replicas never overlap and each one individually
minimizes its own spread (a replica's TP/PP groups are latency-critical; the
replicas themselves share no traffic).  On any :class:`Infeasible` the whole
set rolls back and the error propagates.
"""

from __future__ import annotations

import dataclasses

from repro.core.comm_matrix import CommMatrix, JobSpec, ModelSpec, build_comm_matrix
from repro.core.mip import Infeasible
from repro.core.scheduler import ScheduleRequest, ScheduleResult, Scheduler, get_scheduler
from repro.core.topology import GPUS_PER_NODE, Cluster


def serving_model_spec(cfg, *, batch: int = 32, seq_len: int = 4096) -> ModelSpec:
    """Map an :class:`ArchConfig` (models layer) to the :class:`ModelSpec`
    the comm-volume model (core layer) understands, at serving shapes."""
    return ModelSpec(
        name=f"{cfg.name}-serve", hidden=cfg.d_model, layers=cfg.n_layers,
        vocab=cfg.vocab, seq_len=seq_len, global_batch=batch, micro_batch=1,
        d_ff=cfg.d_ff, n_experts=cfg.n_experts, top_k=cfg.top_k,
        d_expert=cfg.d_expert,
    )


@dataclasses.dataclass(frozen=True)
class ReplicaSpec:
    """One serving replica: a small TP/PP job (node-granular, like any job)."""

    model: ModelSpec
    tp: int = 8
    pp: int = 1
    n_gpus: int = 8

    def job(self) -> JobSpec:
        return JobSpec(n_gpus=self.n_gpus, tp=self.tp, pp=self.pp, model=self.model)

    def comm(self) -> CommMatrix:
        return build_comm_matrix(self.job())

    @property
    def n_nodes(self) -> int:
        return self.n_gpus // GPUS_PER_NODE


@dataclasses.dataclass
class ReplicaPlacement:
    replica_id: int
    result: ScheduleResult
    node_ids: list[int]

    @property
    def method(self) -> str:
        return self.result.method


class ReplicaSet:
    """Placed replicas holding their nodes until :meth:`release`."""

    def __init__(self, cluster: Cluster, placements: list[ReplicaPlacement]):
        self.cluster = cluster
        self.placements = placements
        self._released = False

    @property
    def n_replicas(self) -> int:
        return len(self.placements)

    def node_ids(self) -> list[int]:
        return [n for p in self.placements for n in p.node_ids]

    def minipods_used(self) -> set[int]:
        return {self.cluster.nodes[n].minipod for n in self.node_ids()}

    def release(self) -> None:
        if self._released:
            return
        self.cluster.release(self.node_ids())
        self._released = True


def place_replicas(
    cluster: Cluster,
    n_replicas: int,
    spec: ReplicaSpec,
    *,
    scheduler: "str | Scheduler" = "mip,topo-aware",
    alpha: float = 0.5,
    time_budget: float = 5.0,
    seed: int = 0,
) -> ReplicaSet:
    """Place ``n_replicas`` copies of ``spec`` via the scheduler registry.

    ``scheduler`` is any registry name, comma chain, or instance --
    the default degrades from the MILP to the topo-aware heuristic exactly
    like training placement does.  Allocated nodes roll back if any replica
    is infeasible.
    """
    if n_replicas < 1:
        raise ValueError(f"n_replicas must be >= 1, got {n_replicas}")
    sched = get_scheduler(scheduler)
    placements: list[ReplicaPlacement] = []
    allocated: list[int] = []
    try:
        for r in range(n_replicas):
            result = sched.schedule(ScheduleRequest(
                comm=spec.comm(), cluster=cluster, alpha=alpha,
                time_budget=time_budget, seed=seed + r,
            ))
            ids = result.placement.node_ids()
            cluster.allocate(ids)
            allocated.extend(ids)
            placements.append(ReplicaPlacement(
                replica_id=r, result=result, node_ids=ids,
            ))
    except Infeasible:
        cluster.release(allocated)
        raise
    return ReplicaSet(cluster, placements)
