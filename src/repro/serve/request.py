"""Request/response contract of the serving engine (DESIGN.md §7.2).

Mirrors the scheduler's request/result split (`core/scheduler.py`): a
:class:`GenerationRequest` carries everything the engine needs to produce
tokens, a :class:`GenerationResult` carries everything a benchmark or caller
may want back -- including the per-token completion timestamps the latency
percentiles are computed from.

All timestamps are seconds on the engine's monotonic clock, whose zero is
the start of the *measured window* (after jit warm-up), so token accounting
and throughput derive from exactly the tokens generated inside that window.
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class GenerationRequest:
    """One generation call: prompt tokens + decode budget + arrival time."""

    request_id: int
    prompt: tuple[int, ...]
    max_new_tokens: int
    arrival_s: float = 0.0          # offset from load start (Poisson arrivals)
    eos_id: Optional[int] = None    # stop early on this token if set

    def __post_init__(self):
        if not self.prompt:
            raise ValueError(f"request {self.request_id}: empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError(
                f"request {self.request_id}: max_new_tokens must be >= 1"
            )

    @property
    def worst_case_tokens(self) -> int:
        """Context size the admission controller must budget pages for."""
        return len(self.prompt) + self.max_new_tokens


@dataclasses.dataclass
class GenerationResult:
    """Outcome of one request, with the full latency trail."""

    request_id: int
    prompt: tuple[int, ...]
    tokens: list[int]               # generated tokens, in order
    arrival_s: float
    admitted_s: float               # prefill started (lane + pages granted)
    finished_s: float
    token_times_s: list[float] = dataclasses.field(default_factory=list)
    finish_reason: str = "length"   # "length" | "eos"

    @property
    def n_generated(self) -> int:
        return len(self.tokens)

    @property
    def ttft_s(self) -> float:
        """Time to first token, from arrival (queueing + prefill)."""
        return self.token_times_s[0] - self.arrival_s

    @property
    def e2e_s(self) -> float:
        return self.finished_s - self.arrival_s

    def inter_token_s(self) -> list[float]:
        """Gaps between consecutive generated tokens (decode cadence)."""
        t = self.token_times_s
        return [t[i] - t[i - 1] for i in range(1, len(t))]
