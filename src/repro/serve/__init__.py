"""repro.serve: continuous-batching inference on the repro kernels
(DESIGN.md §7).

* :mod:`repro.serve.kv_cache`  -- paged/blocked KV cache: fixed-size pages,
  free-list allocator, per-sequence block tables
* :mod:`repro.serve.request`   -- GenerationRequest / GenerationResult
* :mod:`repro.serve.engine`    -- continuous-batching engine (mid-flight
  admission, prefill + batched decode, page recycling)
* :mod:`repro.serve.loadgen`   -- seeded Poisson load generator + latency /
  throughput report
* :mod:`repro.serve.placement` -- topology-aware replica placement via the
  unified Scheduler registry
"""

from repro.serve.engine import EngineConfig, EngineStats, ServeEngine
from repro.serve.kv_cache import OutOfPages, PageAllocator, PagedKVCache
from repro.serve.loadgen import (
    LengthMixture,
    LoadGenConfig,
    ServeReport,
    generate_requests,
    run_benchmark,
)
from repro.serve.placement import ReplicaPlacement, ReplicaSet, ReplicaSpec, place_replicas
from repro.serve.request import GenerationRequest, GenerationResult

__all__ = [
    "EngineConfig", "EngineStats", "ServeEngine",
    "OutOfPages", "PageAllocator", "PagedKVCache",
    "LengthMixture", "LoadGenConfig", "ServeReport",
    "generate_requests", "run_benchmark",
    "ReplicaPlacement", "ReplicaSet", "ReplicaSpec", "place_replicas",
    "GenerationRequest", "GenerationResult",
]
