from repro.optim.adamw import (
    AdamWConfig,
    adamw_update,
    clip_by_global_norm,
    global_norm,
    init_opt_state,
)
from repro.optim.schedule import get_schedule, warmup_cosine, wsd

__all__ = [
    "AdamWConfig", "adamw_update", "clip_by_global_norm", "global_norm",
    "init_opt_state", "get_schedule", "warmup_cosine", "wsd",
]
