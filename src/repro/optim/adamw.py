"""AdamW with decoupled weight decay + global-norm clipping, pure JAX.

Optimizer moments are plain pytrees mirroring the params, so the ZeRO-1
sharding rules (``parallel.sharding.opt_shardings``) apply directly.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Union

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: Union[float, Callable] = 3e-4     # float or schedule(step) -> lr
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0                # global-norm clip (0 = off)

    def lr_at(self, step):
        if callable(self.lr):
            return self.lr(step)
        return jnp.asarray(self.lr, jnp.float32)


def init_opt_state(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def adamw_update(params, grads, state, cfg: AdamWConfig):
    """One AdamW step; returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = cfg.lr_at(step)
    if cfg.grad_clip:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    else:
        gnorm = global_norm(grads)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * g32
        v_new = b2 * v + (1 - b2) * jnp.square(g32)
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return p_new.astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_state = {
        "m": jax.tree.unflatten(treedef, [o[1] for o in out]),
        "v": jax.tree.unflatten(treedef, [o[2] for o in out]),
        "step": step,
    }
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
