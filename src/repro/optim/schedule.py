"""LR schedules: linear warmup + cosine decay, and WSD (Warmup-Stable-Decay,
the MiniCPM schedule -- arXiv:2404.06395) used by the minicpm-2b config."""

from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1):
    def f(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / jnp.maximum(warmup_steps, 1)
        prog = jnp.clip(
            (step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0
        )
        cos = final_frac * peak_lr + (1 - final_frac) * peak_lr * 0.5 * (
            1 + jnp.cos(jnp.pi * prog)
        )
        return jnp.where(step < warmup_steps, warm, cos)

    return f


def wsd(peak_lr: float, warmup_steps: int, total_steps: int,
        decay_frac: float = 0.1, final_frac: float = 0.01):
    """Warmup-Stable-Decay: hold peak LR for most of training, then decay
    exponentially in the final ``decay_frac`` of steps."""
    decay_start = int(total_steps * (1.0 - decay_frac))

    def f(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / jnp.maximum(warmup_steps, 1)
        stable = jnp.asarray(peak_lr, jnp.float32)
        prog = jnp.clip(
            (step - decay_start) / jnp.maximum(total_steps - decay_start, 1), 0.0, 1.0
        )
        decay = peak_lr * jnp.power(final_frac, prog)
        out = jnp.where(step < warmup_steps, warm, stable)
        return jnp.where(step >= decay_start, decay, out)

    return f


def get_schedule(name: str, peak_lr: float, warmup_steps: int, total_steps: int):
    if name == "cosine":
        return warmup_cosine(peak_lr, warmup_steps, total_steps)
    if name == "wsd":
        return wsd(peak_lr, warmup_steps, total_steps)
    raise ValueError(f"unknown schedule {name!r}")
