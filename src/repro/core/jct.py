"""ML-driven job-completion-time (JCT) predictor (paper Appendix G).

The paper buckets JCT into 10-minute intervals and trains a gradient
boosting model (GBM [20]) over job metadata (requested CPUs/GPUs, drives,
owner department, ...), reporting RMSE 1.61 buckets on a held-out split.
sklearn/LightGBM are not available offline, so this module implements a
compact gradient-boosted regression-tree ensemble on numpy: exact greedy
splits, L2 loss, shrinkage, subsample bagging (the paper also bags for
uncertainty estimation).
"""

from __future__ import annotations

import dataclasses

import numpy as np

BUCKET_SECONDS = 600.0  # 10-minute intervals (Appendix G)


# --------------------------------------------------------------------- trees
@dataclasses.dataclass
class _Node:
    feature: int = -1
    threshold: float = 0.0
    left: int = -1
    right: int = -1
    value: float = 0.0
    is_leaf: bool = True


class RegressionTree:
    """Depth-limited CART regression tree with exact greedy L2 splits."""

    def __init__(self, max_depth: int = 3, min_leaf: int = 8):
        self.max_depth = max_depth
        self.min_leaf = min_leaf
        self.nodes: list[_Node] = []

    def fit(self, X: np.ndarray, y: np.ndarray) -> "RegressionTree":
        self.nodes = []
        self._build(X, y, np.arange(len(y)), depth=0)
        return self

    def _build(self, X, y, idx, depth) -> int:
        node_id = len(self.nodes)
        node = _Node(value=float(np.mean(y[idx])))
        self.nodes.append(node)
        if depth >= self.max_depth or len(idx) < 2 * self.min_leaf:
            return node_id
        best = self._best_split(X, y, idx)
        if best is None:
            return node_id
        f, thr = best
        mask = X[idx, f] <= thr
        li, ri = idx[mask], idx[~mask]
        node.is_leaf = False
        node.feature, node.threshold = f, thr
        node.left = self._build(X, y, li, depth + 1)
        node.right = self._build(X, y, ri, depth + 1)
        return node_id

    def _best_split(self, X, y, idx):
        n = len(idx)
        base_sum, base_sq = y[idx].sum(), (y[idx] ** 2).sum()
        base_err = base_sq - base_sum**2 / n
        best_gain, best = 1e-12, None
        for f in range(X.shape[1]):
            order = idx[np.argsort(X[idx, f], kind="stable")]
            xs, ys = X[order, f], y[order]
            csum = np.cumsum(ys)
            csq = np.cumsum(ys**2)
            for i in range(self.min_leaf, n - self.min_leaf):
                if xs[i] == xs[i - 1]:
                    continue
                ls, lq = csum[i - 1], csq[i - 1]
                rs, rq = base_sum - ls, base_sq - lq
                err = (lq - ls**2 / i) + (rq - rs**2 / (n - i))
                gain = base_err - err
                if gain > best_gain:
                    best_gain = gain
                    best = (f, float((xs[i] + xs[i - 1]) / 2))
        return best

    def predict(self, X: np.ndarray) -> np.ndarray:
        out = np.empty(len(X))
        for r in range(len(X)):
            i = 0
            while not self.nodes[i].is_leaf:
                nd = self.nodes[i]
                i = nd.left if X[r, nd.feature] <= nd.threshold else nd.right
            out[r] = self.nodes[i].value
        return out


# ----------------------------------------------------------------------- GBM
class GBMRegressor:
    """Gradient boosting with L2 loss, shrinkage and row subsampling."""

    def __init__(
        self,
        n_rounds: int = 60,
        learning_rate: float = 0.15,
        max_depth: int = 3,
        subsample: float = 0.8,
        min_leaf: int = 8,
        seed: int = 0,
    ):
        self.n_rounds = n_rounds
        self.lr = learning_rate
        self.max_depth = max_depth
        self.subsample = subsample
        self.min_leaf = min_leaf
        self.seed = seed
        self.base_: float = 0.0
        self.trees_: list[RegressionTree] = []

    def fit(self, X: np.ndarray, y: np.ndarray) -> "GBMRegressor":
        rng = np.random.default_rng(self.seed)
        X = np.asarray(X, dtype=float)
        y = np.asarray(y, dtype=float)
        self.base_ = float(np.mean(y))
        pred = np.full(len(y), self.base_)
        self.trees_ = []
        for _ in range(self.n_rounds):
            resid = y - pred
            if self.subsample < 1.0:
                sel = rng.random(len(y)) < self.subsample
                if sel.sum() < 4 * self.min_leaf:
                    sel = np.ones(len(y), dtype=bool)
            else:
                sel = np.ones(len(y), dtype=bool)
            tree = RegressionTree(self.max_depth, self.min_leaf).fit(X[sel], resid[sel])
            self.trees_.append(tree)
            pred += self.lr * tree.predict(X)
        return self

    def predict(self, X: np.ndarray) -> np.ndarray:
        X = np.asarray(X, dtype=float)
        pred = np.full(len(X), self.base_)
        for t in self.trees_:
            pred += self.lr * t.predict(X)
        return pred


# --------------------------------------------------------------- JCT wrapper
#: metadata feature order used by the predictor (paper Appendix G).
JOB_FEATURES = (
    "n_gpus",
    "n_cpus",
    "mem_gb",
    "n_drives",
    "department",     # categorical, integer-coded (trees split natively)
    "priority",
    "hour_of_day",
    "user_avg_jct",   # historical average per owner
)


class JCTPredictor:
    """Coarse-grained JCT forecaster: predicts the 10-minute bucket index."""

    def __init__(self, n_bags: int = 5, **gbm_kw):
        self.n_bags = n_bags
        self.gbm_kw = gbm_kw
        self.models_: list[GBMRegressor] = []

    @staticmethod
    def featurize(jobs: list[dict]) -> np.ndarray:
        return np.array(
            [[float(j.get(f, 0.0)) for f in JOB_FEATURES] for j in jobs]
        )

    @staticmethod
    def to_bucket(jct_seconds: np.ndarray) -> np.ndarray:
        return np.floor(np.asarray(jct_seconds) / BUCKET_SECONDS)

    def fit(self, jobs: list[dict], jct_seconds: np.ndarray) -> "JCTPredictor":
        X = self.featurize(jobs)
        y = self.to_bucket(jct_seconds)
        self.models_ = [
            GBMRegressor(seed=b, **self.gbm_kw).fit(X, y) for b in range(self.n_bags)
        ]
        return self

    def predict_bucket(self, jobs: list[dict]) -> np.ndarray:
        X = self.featurize(jobs)
        preds = np.stack([m.predict(X) for m in self.models_])
        return preds.mean(axis=0)

    def predict_seconds(self, jobs: list[dict]) -> np.ndarray:
        # Upper edge of the predicted bucket: conservative for reservations.
        return (np.maximum(self.predict_bucket(jobs), 0.0) + 1.0) * BUCKET_SECONDS

    def uncertainty(self, jobs: list[dict]) -> np.ndarray:
        X = self.featurize(jobs)
        preds = np.stack([m.predict(X) for m in self.models_])
        return preds.std(axis=0)


# ------------------------------------------------------------ synthetic trace
def synthetic_trace(n_jobs: int, seed: int = 0) -> tuple[list[dict], np.ndarray]:
    """Synthetic cluster trace with learnable JCT structure, used to
    reproduce the Appendix G experiment shape (RMSE in bucket units)."""
    rng = np.random.default_rng(seed)
    jobs, jct = [], []
    for _ in range(n_jobs):
        dept = int(rng.integers(0, 6))
        n_gpus = int(2 ** rng.integers(0, 9))  # 1..256
        n_cpus = n_gpus * int(rng.integers(4, 12))
        mem = n_gpus * float(rng.integers(32, 128))
        drives = int(rng.integers(0, 8))
        priority = int(rng.integers(0, 3))
        hour = int(rng.integers(0, 24))
        user_avg = float(rng.lognormal(mean=7.2 + 0.2 * dept, sigma=0.4))
        base = (
            600
            + 70.0 * np.log2(max(n_gpus, 1)) ** 2
            + 260.0 * dept
            + 0.45 * user_avg
            + 320.0 * drives * (dept % 2)
        )
        noise = rng.lognormal(mean=0.0, sigma=0.22)
        jct.append(base * noise)
        jobs.append(
            dict(
                n_gpus=n_gpus,
                n_cpus=n_cpus,
                mem_gb=mem,
                n_drives=drives,
                department=dept,
                priority=priority,
                hour_of_day=hour,
                user_avg_jct=user_avg,
            )
        )
    return jobs, np.array(jct)
