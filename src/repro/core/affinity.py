"""Affinity calibration from the characterization database (paper §5.2).

The trade-off between aligning DP vs PP groups depends on model config and
GPU type (§4, Appendix E).  LPJs are pre-characterized: the database stores,
per profiled job, the fingerprint ratios

    r1 = mb * v_w / (v_d + v_p)     (computation-to-communication)
    r2 = v_d / v_p                  (DP-to-PP volume)

together with the measured improvements of DP-aligned / PP-aligned placement
``(j_dp, j_pp)``.  Online scheduling finds the nearest profiled job by
Euclidean distance in (r1, r2) and derives

    alpha = j_dp / (j_dp + j_pp),   beta = j_pp / (j_dp + j_pp).

The shipped database is seeded with the paper's published data points
(24B dense / 24B MoE on H800; 7B / 14B dense on L20, Appendix E Table 2).
"""

from __future__ import annotations

import dataclasses
import json
import math
import pathlib

from repro.core.comm_matrix import CommMatrix


@dataclasses.dataclass(frozen=True)
class CharRecord:
    """One pre-characterization entry: <GPU_type, j_dp, j_pp> plus ratios."""

    gpu_type: str
    model_name: str
    r1: float
    r2: float
    j_dp: float  # % improvement of DP-aligned placement over worst
    j_pp: float  # % improvement of PP-aligned placement over worst
    unit: str = "pp"  # scheduling unit chosen for this profile

    def affinity(self) -> tuple[float, float]:
        tot = self.j_dp + self.j_pp
        if tot <= 0:
            return 0.5, 0.5
        return self.j_dp / tot, self.j_pp / tot


# Paper-published calibration points.  r1/r2 are recomputed from the
# analytical model for representative configs (see tests/test_affinity.py);
# j_dp/j_pp come from §4 (Fig. 5a) and Appendix E (Table 2).
_PAPER_SEED = [
    # H800: 24B dense -- PP dominates ("alpha set to zero"); dp-aligned no
    # speedup, pp-aligned +2.3%.
    CharRecord("H800", "dense-24b", r1=180.0, r2=60.0, j_dp=0.0, j_pp=2.3),
    # H800: 24B MoE -- alpha=0.3 / beta=0.7 in the paper.
    CharRecord("H800", "moe-24b", r1=40.0, r2=25.0, j_dp=0.3, j_pp=0.7),
    # L20 (Ada Lovelace, fp8 activations halve PP volume): 7B dense,
    # DP-aligned wins by 1.4%.
    CharRecord("L20", "dense-7b", r1=120.0, r2=130.0, j_dp=1.4, j_pp=0.0),
    # L20: 14B dense, PP-aligned wins by 0.5%.
    CharRecord("L20", "dense-14b", r1=150.0, r2=90.0, j_dp=0.0, j_pp=0.5),
]


class CharacterizationDB:
    """Nearest-neighbour lookup over profiled jobs (Euclidean in (r1, r2))."""

    def __init__(self, records: list[CharRecord] | None = None):
        self.records: list[CharRecord] = list(records) if records else list(_PAPER_SEED)

    def add(self, rec: CharRecord) -> None:
        self.records.append(rec)

    def lookup(self, r1: float, r2: float, gpu_type: str | None = None) -> CharRecord:
        cands = [
            r for r in self.records if gpu_type is None or r.gpu_type == gpu_type
        ] or self.records
        return min(
            cands, key=lambda r: math.hypot(r.r1 - r1, r.r2 - r2)
        )

    def affinity_for(self, comm: CommMatrix) -> tuple[float, float, str]:
        """(alpha, beta, scheduling_unit) for a job's communication matrix."""
        r1, r2 = comm.ratios()
        rec = self.lookup(r1, r2, comm.job.gpu_type)
        a, b = rec.affinity()
        return a, b, rec.unit

    # Persistence -- the paper stores characterization results in a database
    # consulted during online scheduling.
    def save(self, path: str | pathlib.Path) -> None:
        data = [dataclasses.asdict(r) for r in self.records]
        pathlib.Path(path).write_text(json.dumps(data, indent=2))

    @classmethod
    def load(cls, path: str | pathlib.Path) -> "CharacterizationDB":
        data = json.loads(pathlib.Path(path).read_text())
        return cls([CharRecord(**r) for r in data])
