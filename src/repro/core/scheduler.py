"""Unified scheduler API: one request/result contract for every placement
algorithm (DESIGN.md §2.4).

Arnold's value is that a *single* placement contract flows from workload
characterization to the training framework (paper §5-§6).  This module makes
that contract explicit:

* :class:`ScheduleRequest`  -- everything a placement decision needs (comm
  matrix, cluster, affinity weights, scheduling unit, excluded/reserved node
  sets, solver time budget, RNG seed);
* :class:`ScheduleResult`   -- everything a caller may want back (placement,
  objective, per-axis max spreads, solve stats, method string);
* :class:`Scheduler`        -- the protocol: ``schedule(request) -> result``;
* a string-keyed registry (:func:`register_scheduler`, :func:`get_scheduler`,
  :func:`list_schedulers`) over which the MILP and all four baselines are
  exposed as interchangeable policies;
* :class:`FallbackChain`    -- the first composite the redesign enables:
  try policies in order, degrading gracefully on :class:`Infeasible` or
  solver time-budget exhaustion (e.g. ``FallbackChain("mip", "topo-aware")``).

The legacy entry points (``schedule_mip`` and the baseline functions in
:mod:`repro.core.baselines`) are deprecated thin shims over this registry
(they warn on call); the registry is the only supported entry point
(DESIGN.md §2.4).  The ``"hier"`` scale tier
(:mod:`repro.core.hierarchical`) registers here too and composes as
``FallbackChain("hier", "mip", "topo-aware")``.
"""

from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Callable, Iterator, Optional, Protocol, runtime_checkable

import numpy as np

from repro.core.comm_matrix import CommMatrix
from repro.core.mip import Infeasible, _counts_to_placement, _solve_counts
from repro.core.spread import Placement, max_spreads, weighted_spread
from repro.core.topology import Cluster


# ---------------------------------------------------------------------------
# Request / result contract
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ScheduleRequest:
    """One placement problem, algorithm-agnostic.

    ``alpha``/``beta`` are the Eq. 2 affinity weights (``beta`` defaults to
    ``1 - alpha``); ``unit`` picks the scheduling-unit group ("pp" rows or
    "dp" columns, §5.2).  ``excluded_nodes`` are unusable (failed/unhealthy)
    nodes; ``reserved_nodes`` are held for another job -- both are masked
    from the free pool for the duration of the solve.  ``time_budget`` caps
    solver wall-clock (MILP time limit); heuristic policies ignore it.
    ``seed``/``rng`` make randomized policies reproducible (``rng`` wins
    when both are given).

    ``prev_placement``/``dirty_nodes`` are the warm-start contract
    (DESIGN.md §8.2): a caller re-solving after incremental churn (a
    failure, a few nodes drained) passes the placement it already has plus
    the set of node ids that changed; a warm-start-capable scheduler
    ("hier") repairs the placement locally instead of re-solving from
    scratch, and every other scheduler simply ignores the hint -- so the
    fields are safe to set unconditionally.
    """

    comm: CommMatrix
    cluster: Cluster
    alpha: float = 0.5
    beta: Optional[float] = None
    unit: str = "pp"
    excluded_nodes: frozenset[int] = frozenset()
    reserved_nodes: frozenset[int] = frozenset()
    time_budget: float = 10.0
    seed: int = 0
    rng: Optional[np.random.Generator] = None
    prev_placement: Optional[Placement] = None
    dirty_nodes: frozenset[int] = frozenset()
    options: dict = dataclasses.field(default_factory=dict)  # method-specific

    def __post_init__(self):
        if self.unit not in ("pp", "dp"):
            raise ValueError(f"unit must be pp|dp, got {self.unit}")
        if self.alpha < 0:
            raise ValueError(f"alpha must be >= 0, got {self.alpha}")
        self.excluded_nodes = frozenset(self.excluded_nodes)
        self.reserved_nodes = frozenset(self.reserved_nodes)
        self.dirty_nodes = frozenset(self.dirty_nodes)

    def resolved_beta(self) -> float:
        return 1.0 - self.alpha if self.beta is None else self.beta

    def resolved_rng(self) -> np.random.Generator:
        return self.rng if self.rng is not None else np.random.default_rng(self.seed)

    def masked_nodes(self) -> frozenset[int]:
        return self.excluded_nodes | self.reserved_nodes

    @contextlib.contextmanager
    def masked_cluster(self) -> Iterator[Cluster]:
        """Cluster view with excluded/reserved nodes taken out of the free
        pool; the cluster's free set is restored on exit."""
        mask = [n for n in sorted(self.masked_nodes()) if self.cluster.is_free(n)]
        self.cluster.allocate(mask)
        try:
            yield self.cluster
        finally:
            self.cluster.release(mask)


@dataclasses.dataclass
class ScheduleResult:
    """Outcome of one placement decision.

    ``objective`` is method-specific (the MILP's Eq. 4 value for "mip", the
    Eq. 2 weighted spread for the heuristics); ``dp_spread``/``pp_spread``
    are the method-independent comparison metric (Eq. 3 max spreads).
    ``method`` records what actually produced the placement ("milp",
    "greedy-proven-optimal", a baseline name, ...); ``stats`` carries
    method-specific extras (MILP counts, fallback-chain attempts, ...).
    """

    placement: Placement
    objective: float
    dp_spread: int
    pp_spread: int
    solve_seconds: float
    method: str
    stats: dict = dataclasses.field(default_factory=dict)

    def n_pods_used(self) -> int:
        """Distinct fabric domains (minipods on ``clos``) the placement
        touches."""
        return int(len(np.unique(self.placement.domain_of())))

    def weighted_spread(self, alpha: float, beta: Optional[float] = None) -> float:
        """Eq. 2 metric of this placement (validates ``alpha + beta == 1``)."""
        return weighted_spread(self.placement, alpha, beta)


@runtime_checkable
class Scheduler(Protocol):
    """Anything that turns a :class:`ScheduleRequest` into a
    :class:`ScheduleResult` (raising :class:`Infeasible` when it cannot)."""

    name: str

    def schedule(self, request: ScheduleRequest) -> ScheduleResult:
        ...


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, Scheduler] = {}
_ALIASES = {"milp": "mip", "arnold": "mip", "hierarchical": "hier", "scale": "hier"}


def _canon(name: str) -> str:
    key = name.strip().lower().replace("_", "-")
    return _ALIASES.get(key, key)


def register_scheduler(
    name: str, scheduler: Optional[Scheduler] = None, *, overwrite: bool = False
):
    """Register ``scheduler`` under ``name`` (also usable as a decorator on a
    Scheduler class, which is instantiated with no arguments)."""
    def _register(obj):
        sched = obj() if isinstance(obj, type) else obj
        key = _canon(name)
        if key in _REGISTRY and not overwrite:
            raise ValueError(f"scheduler {key!r} already registered")
        _REGISTRY[key] = sched
        return obj

    return _register if scheduler is None else _register(scheduler)


def get_scheduler(spec: "str | Scheduler") -> Scheduler:
    """Resolve a scheduler by name or pass an instance through.

    Names are case-insensitive and ``_``/``-`` agnostic ("topo_aware" ==
    "topo-aware"); a comma-separated list ("mip,topo-aware") resolves to a
    :class:`FallbackChain` over the parts.
    """
    if not isinstance(spec, str):
        if isinstance(spec, Scheduler):
            return spec
        raise TypeError(f"expected scheduler name or instance, got {type(spec)}")
    if "," in spec:
        return FallbackChain(*[part for part in spec.split(",") if part.strip()])
    key = _canon(spec)
    try:
        return _REGISTRY[key]
    except KeyError:
        raise KeyError(
            f"unknown scheduler {spec!r}; available: {list_schedulers()}"
        ) from None


def list_schedulers() -> list[str]:
    """Canonical names of all registered schedulers (aliases excluded)."""
    return sorted(_REGISTRY)


# ---------------------------------------------------------------------------
# Concrete schedulers
# ---------------------------------------------------------------------------

class MipScheduler:
    """Arnold's MILP (Eq. 4-10) behind the unified contract.

    ``request.time_budget`` is the solver time limit; ``request.options``
    accepts the MILP knobs ``integral_nodes`` (default True) and
    ``use_greedy_bound`` (default True).
    """

    name = "mip"

    def schedule(self, request: ScheduleRequest) -> ScheduleResult:
        comm = request.comm
        beta = request.resolved_beta()
        n_groups = comm.n_rows if request.unit == "pp" else comm.n_cols
        group_size = comm.n_cols if request.unit == "pp" else comm.n_rows
        with request.masked_cluster() as cluster:
            free = np.array(cluster.free_capacities(), dtype=float)
            counts, obj, dt, method = _solve_counts(
                group_size,
                n_groups,
                free,
                request.alpha,
                beta,
                request.options.get("integral_nodes", True),
                request.time_budget,
                use_greedy_bound=request.options.get("use_greedy_bound", True),
            )
            placement = _counts_to_placement(comm, cluster, counts, request.unit)
        dp_s, pp_s = max_spreads(placement)
        return ScheduleResult(
            placement=placement,
            objective=obj,
            dp_spread=dp_s,
            pp_spread=pp_s,
            solve_seconds=dt,
            method=method,
            stats={
                "counts": counts,
                "n_pods_used": int((counts.sum(axis=0) > 0).sum()),
                "max_unit_spread": int(max((row > 0).sum() for row in counts)),
            },
        )


class FunctionScheduler:
    """Adapts a ``fn(comm, cluster, **kw) -> Placement`` heuristic to the
    Scheduler protocol (used for the four §7.1 baselines)."""

    def __init__(self, name: str, fn: Callable[..., Placement], *, wants_rng: bool = False):
        self.name = name
        self._fn = fn
        self._wants_rng = wants_rng

    def schedule(self, request: ScheduleRequest) -> ScheduleResult:
        t0 = time.perf_counter()
        kw = {"rng": request.resolved_rng()} if self._wants_rng else {}
        with request.masked_cluster() as cluster:
            placement = self._fn(request.comm, cluster, **kw)
        dt = time.perf_counter() - t0
        dp_s, pp_s = max_spreads(placement)
        return ScheduleResult(
            placement=placement,
            objective=request.alpha * dp_s + request.resolved_beta() * pp_s,
            dp_spread=dp_s,
            pp_spread=pp_s,
            solve_seconds=dt,
            method=self.name,
        )


class FallbackChain:
    """Try schedulers in order; return the first feasible, on-time result.

    Links may be names or instances and are resolved lazily at schedule
    time, so a chain can reference policies registered after construction.
    ``request.time_budget`` is the budget for the *whole chain*: each link
    runs with the budget remaining when it starts, and a link fails either
    by raising :class:`Infeasible` -- which the MILP also raises on
    time-budget exhaustion without an incumbent -- or by returning only
    after its remaining budget is spent (a placement delivered past the
    deadline is useless to a real-time scheduling loop, so the chain
    discards it and degrades to the next, cheaper link).  The final link is
    exempt from the overrun check: a late placement beats no placement.

    The winning result's ``stats["served_by"]`` records which link
    produced it and ``stats["fallbacks"]`` the failed attempts; if every
    link fails, one aggregate :class:`Infeasible` is raised.
    """

    def __init__(self, *schedulers: "str | Scheduler", name: Optional[str] = None):
        if not schedulers:
            raise ValueError("FallbackChain needs at least one scheduler")
        self._links = list(schedulers)
        self.name = name or "fallback(" + ",".join(
            s if isinstance(s, str) else getattr(s, "name", type(s).__name__)
            for s in schedulers
        ) + ")"

    def schedule(self, request: ScheduleRequest) -> ScheduleResult:
        failures: list[tuple[str, str]] = []
        t_start = time.perf_counter()
        for i, link in enumerate(self._links):
            sched = get_scheduler(link)
            remaining = request.time_budget - (time.perf_counter() - t_start)
            if remaining <= 0 and i < len(self._links) - 1:
                # Out of budget: skip straight to the last (cheapest) link
                # rather than burning more time on expensive middle links.
                failures.append((sched.name, "chain time budget exhausted"))
                continue
            sub = dataclasses.replace(request, time_budget=max(remaining, 0.0))
            t_link = time.perf_counter()
            try:
                result = sched.schedule(sub)
            except Infeasible as exc:
                failures.append((sched.name, str(exc)))
                continue
            elapsed = time.perf_counter() - t_link
            if elapsed > remaining and i < len(self._links) - 1:
                failures.append((
                    sched.name,
                    f"exceeded time budget ({elapsed:.3f}s > {remaining:.3f}s)",
                ))
                continue
            result.stats = dict(result.stats, served_by=sched.name)
            if failures:
                result.stats["fallbacks"] = list(failures)
            return result
        detail = "; ".join(f"{n}: {msg}" for n, msg in failures)
        raise Infeasible(f"all schedulers in {self.name} failed: {detail}")


def _register_builtin_schedulers() -> None:
    # Imported here (not at module top) only to keep the privates' origin
    # obvious; baselines.py itself never imports this module at import time,
    # so there is no cycle either way.  hierarchical.py *does* import this
    # module, but by the time this function runs (module bottom) every name
    # it needs is defined.
    from repro.core import baselines
    from repro.core.hierarchical import HierarchicalScheduler

    register_scheduler("mip", MipScheduler())
    register_scheduler("hier", HierarchicalScheduler())
    register_scheduler("best-fit", FunctionScheduler("best-fit", baselines._best_fit))
    register_scheduler(
        "random-fit",
        FunctionScheduler("random-fit", baselines._random_fit, wants_rng=True),
    )
    register_scheduler(
        "gpu-packing", FunctionScheduler("gpu-packing", baselines._gpu_packing)
    )
    register_scheduler(
        "topo-aware", FunctionScheduler("topo-aware", baselines._topo_aware)
    )


_register_builtin_schedulers()
