"""Spread metric and scheduling objective (paper §5.2, Eq. 2-3), fabric-generic.

The *spread* of a communication group is the number of locality domains
its members straddle (minipods on the paper's CLOS fabric), derived from
the discrete distance over one-hot placement vectors (Eq. 3): position
``i`` contributes 1 iff two members disagree there, so a group inside one
domain has distance 0, and a group spanning ``q > 1`` domains has distance
``q``.  The scheduling objective (Eq. 2) is the weighted sum of the
*maximum* spread over DP groups (weight alpha) and PP groups (weight
beta) -- max, because the slowest group stragglers the synchronous step.

On fabrics with graded locality (torus, dragonfly) the domain count alone
under-describes a placement, so :func:`max_hop_diameters` additionally
reports each axis's worst *hop diameter* -- the max pairwise fabric
distance among the domains a group touches -- which is what the
per-fabric network models consume (DESIGN.md §9.3).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.comm_matrix import CommMatrix
from repro.core.topology import Cluster


@dataclasses.dataclass
class Placement:
    """A complete placement of a communication matrix onto a cluster.

    ``assignment[r, c]`` is the node id hosting matrix cell (r, c); rows are
    PP groups, columns are DP groups.
    """

    comm: CommMatrix
    assignment: np.ndarray  # (n_rows, n_cols) of node ids
    cluster: Cluster

    def __post_init__(self):
        a = np.asarray(self.assignment)
        if a.shape != self.comm.shape:
            raise ValueError(f"assignment shape {a.shape} != matrix {self.comm.shape}")
        if len(np.unique(a)) != a.size:
            raise ValueError("assignment maps two cells to the same node")
        self.assignment = a

    def domain_of(self) -> np.ndarray:
        """Fabric domain id per cell, same shape as the matrix.

        One fancy-indexing gather through the cluster's precomputed
        node->domain array -- this is on the hot path of every spread
        evaluation (it used to be a per-cell ``np.vectorize`` Python
        lookup)."""
        return self.cluster.domain_index[self.assignment]

    def minipod_of(self) -> np.ndarray:
        """Historical ``clos`` name for :meth:`domain_of`; identical output
        on every fabric (minipods are the clos fabric's domains)."""
        return self.domain_of()

    def node_ids(self) -> list[int]:
        return [int(n) for n in self.assignment.ravel()]


def distance_onehot(vectors: np.ndarray) -> int:
    """Eq. 3, literally: ``vectors`` is (n_members, k) one-hot rows.

    D = |{i : exists j != l with v_j[i] != v_l[i]}|.
    """
    v = np.asarray(vectors)
    if v.ndim != 2:
        raise ValueError("expected (n, k) one-hot matrix")
    differs = np.any(v != v[0], axis=0)  # column differs from first member
    return int(np.count_nonzero(differs))


def group_spread(domains: np.ndarray, k: int | None = None) -> int:
    """Spread of one group given integer domain assignments.

    Equivalent to ``distance_onehot`` on the one-hot encoding: 0 when all
    members share a domain, else the number of distinct domains.
    """
    u = np.unique(np.asarray(domains))
    return 0 if len(u) <= 1 else int(len(u))


def group_hop_diameter(domains: np.ndarray, cluster: Cluster) -> int:
    """Worst pairwise fabric hop distance among the domains of one group
    (0 when the group sits in a single domain)."""
    u = np.unique(np.asarray(domains))
    if len(u) <= 1:
        return 0
    return max(
        cluster.domain_distance(int(a), int(b))
        for i, a in enumerate(u)
        for b in u[i + 1:]
    )


def max_spreads(placement: Placement) -> tuple[int, int]:
    """(max DP-group spread, max PP-group spread) of a placement."""
    pods = placement.domain_of()
    pp_spread = max(group_spread(pods[r, :]) for r in range(pods.shape[0]))
    dp_spread = max(group_spread(pods[:, c]) for c in range(pods.shape[1]))
    return dp_spread, pp_spread


def max_hop_diameters(placement: Placement) -> tuple[int, int]:
    """(max DP-group hop diameter, max PP-group hop diameter).

    On ``clos`` every multi-domain group has the same diameter (all
    minipods are equidistant through the core); on torus/dragonfly this is
    the locality signal the per-fabric network models run on.
    """
    pods = placement.domain_of()
    cluster = placement.cluster
    pp = max(group_hop_diameter(pods[r, :], cluster) for r in range(pods.shape[0]))
    dp = max(group_hop_diameter(pods[:, c], cluster) for c in range(pods.shape[1]))
    return dp, pp


def weighted_spread(placement: Placement, alpha: float, beta: float | None = None) -> float:
    """Eq. 2: alpha * max_j D(DP group j) + beta * max_i D(PP group i).

    ``alpha`` is the DP affinity, ``beta`` the PP affinity; ``alpha+beta=1``.
    This is the metric used to benchmark scheduling algorithms (§7.1).
    """
    if beta is None:
        beta = 1.0 - alpha
    if not np.isclose(alpha + beta, 1.0):
        raise ValueError(f"alpha+beta must be 1, got {alpha}+{beta}")
    dp_s, pp_s = max_spreads(placement)
    return alpha * dp_s + beta * pp_s


def mean_spreads(placement: Placement) -> tuple[float, float]:
    """Average (not max) spreads -- reported alongside the paper metric."""
    pods = placement.domain_of()
    pp = float(np.mean([group_spread(pods[r, :]) for r in range(pods.shape[0])]))
    dp = float(np.mean([group_spread(pods[:, c]) for c in range(pods.shape[1])]))
    return dp, pp
