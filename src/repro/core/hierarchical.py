"""Hierarchical scale tier: sub-second scheduling at 10k-node scale
(DESIGN.md §8, grounded in the fast-repeatable-placement stage of
arXiv:2411.11560).

The flat MILP in :mod:`repro.core.mip` solves one problem whose variable
count is ``n_groups * n_minipods`` -- fine at the paper's 11-minipod
settings, hopeless under a 1 s budget when the cluster has 100+ minipods.
This tier keeps the paper's Eq. 2 spread objective but decomposes the
solve so cost scales with the *pods a job touches*, not cluster size:

1. **Coarse stage** -- minipods are grouped into contiguous *blocks* of
   ``pods_per_block``; one small MILP (reusing :func:`mip._solve_counts`
   with block-aggregate capacities) decides how many nodes of each
   scheduling-unit group land in each block.
2. **Fine stage** -- per selected block, an *independent* minipod-level
   MILP places the whole groups assigned to that block; seam groups that
   straddle blocks are placed by a best-fit splitter.  Blocks the coarse
   stage did not select are never looked at.
3. **Warm-start re-solve** -- when the request carries ``prev_placement``
   and a small ``dirty_nodes`` set (failure churn, the path
   ``FailureManager``/``TraceSimulator`` exercise), the previous placement
   is repaired locally (same-pod free node first, then pods the affected
   groups already span) instead of re-solving from scratch.
4. **Placement cache** -- solved counts matrices are memoized in a
   :class:`repro.core.placement_cache.PlacementCache` keyed on (matrix
   shape, unit, weights, quantized free signature), so recurring job
   shapes skip the solve entirely.

When the cluster fits in a single block the tier degenerates to the flat
MILP (identical counts), which is how the paper-setting spread parity is
guaranteed.  Registered as ``"hier"``; composes as
``FallbackChain("hier", "mip", "topo-aware")``.
"""

from __future__ import annotations

import time
from typing import Optional

import numpy as np

from repro.core.mip import (
    Infeasible,
    _counts_objective,
    _counts_to_placement,
    _solve_counts,
)
from repro.core.placement_cache import PlacementCache
from repro.core.spread import Placement, max_spreads
from repro.core.topology import Cluster

# Fraction of the time budget handed to the coarse block-level solve; the
# remainder is split evenly across the active blocks' fine solves.
_COARSE_BUDGET_FRAC = 0.4
_MIN_STAGE_BUDGET = 0.05


class HierarchicalScheduler:
    """Pod-block decomposition + warm-start + placement cache ("hier").

    ``request.options`` knobs:

    * ``pods_per_block`` (default 16) -- minipods per coarse block; paper
      settings (<= 11 minipods) collapse to one block = flat MILP.
    * ``repair_max_dirty`` (default 8) -- warm-start repair is attempted
      only when at most this many placed nodes are dirty; larger churn
      falls through to a cold solve.
    * ``use_cache`` (default True) -- consult/fill the placement cache.
    * ``integral_nodes`` / ``use_greedy_bound`` -- passed to the MILP
      stages (same meaning as for ``"mip"``).
    """

    name = "hier"

    def __init__(self, pods_per_block: int = 16, cache: Optional[PlacementCache] = None):
        self.pods_per_block = pods_per_block
        self.cache = cache if cache is not None else PlacementCache()

    # ----------------------------------------------------------------- entry
    def schedule(self, request) -> "ScheduleResult":
        from repro.core.scheduler import ScheduleResult  # cycle-free at call time

        t0 = time.perf_counter()
        warm = self._try_repair(request)
        if warm is not None:
            return warm

        alpha, beta = request.alpha, request.resolved_beta()
        comm = request.comm
        n_groups = comm.n_rows if request.unit == "pp" else comm.n_cols
        group_size = comm.n_cols if request.unit == "pp" else comm.n_rows
        ppb = int(request.options.get("pods_per_block", self.pods_per_block))
        use_cache = bool(request.options.get("use_cache", True))

        with request.masked_cluster() as cluster:
            free = np.array(cluster.free_capacities(), dtype=float)
            blocks = cluster.scheduling_blocks(ppb)
            cache_key = self.cache.key(
                comm, cluster, request.unit, alpha, beta, extra=("ppb", ppb)
            )
            counts = self.cache.lookup(cache_key, free) if use_cache else None
            cached = counts is not None
            stage_stats: dict = {}
            if counts is None:
                counts, stage_stats = self._solve_hierarchical(
                    group_size, n_groups, free, alpha, beta, request, blocks
                )
                if use_cache:
                    self.cache.store(cache_key, counts)
            placement = _counts_to_placement(comm, cluster, counts, request.unit)

        dp_s, pp_s = max_spreads(placement)
        dt = time.perf_counter() - t0
        stats = {
            "counts": counts,
            "n_pods_used": int((counts.sum(axis=0) > 0).sum()),
            "max_unit_spread": int(max((row > 0).sum() for row in counts)),
            "warm_start": False,
            "cache": dict(self.cache.stats.as_dict(), hit=cached),
            **stage_stats,
        }
        return ScheduleResult(
            placement=placement,
            objective=_counts_objective(counts, alpha, beta),
            dp_spread=dp_s,
            pp_spread=pp_s,
            solve_seconds=dt,
            method="hier-cached" if cached else "hier",
            stats=stats,
        )

    # ------------------------------------------------------- hierarchical solve
    def _solve_hierarchical(
        self,
        group_size: int,
        n_groups: int,
        free: np.ndarray,
        alpha: float,
        beta: float,
        request,
        blocks: list[list[int]],
    ) -> tuple[np.ndarray, dict]:
        """Coarse block solve + independent per-block fine solves.

        ``blocks`` is the fabric's locality-coherent domain grouping
        (:meth:`Cluster.scheduling_blocks`) -- contiguous id ranges on
        ``clos`` (identical to the pre-fabric behaviour), torus slabs /
        dragonfly groups elsewhere.  Returns the global
        ``(n_groups, n_domains)`` counts and per-stage stats.  A
        single-block cluster short-circuits to the flat MILP.
        """
        k = len(free)
        integral = request.options.get("integral_nodes", True)
        greedy = request.options.get("use_greedy_bound", True)
        budget = request.time_budget

        if len(blocks) == 1:
            counts, _, _, method = _solve_counts(
                group_size, n_groups, free, alpha, beta, integral, budget,
                use_greedy_bound=greedy,
            )
            return counts, {"n_blocks": 1, "blocks_touched": 1,
                            "coarse_method": "flat", "fine_methods": [method]}

        t0 = time.perf_counter()
        block_free = np.array([free[blk].sum() for blk in blocks], dtype=float)
        coarse_budget = max(_MIN_STAGE_BUDGET, budget * _COARSE_BUDGET_FRAC)
        coarse, _, _, coarse_method = _solve_counts(
            group_size, n_groups, block_free, alpha, beta, True, coarse_budget,
            use_greedy_bound=greedy,
        )

        counts = np.zeros((n_groups, k), dtype=int)
        active = [b for b in range(len(blocks)) if coarse[:, b].sum() > 0]
        fine_methods: list[str] = []
        for bi, b in enumerate(active):
            blk = blocks[b]
            demands = coarse[:, b]
            work = free[blk].astype(float).copy()
            whole = [g for g in range(n_groups) if demands[g] == group_size]
            partial = [g for g in range(n_groups) if 0 < demands[g] < group_size]
            # Seam groups first: they have hard per-block demands, and
            # placing them up front keeps the whole-group MILP feasible
            # (total block capacity >= total block demand by construction).
            for g in sorted(partial, key=lambda g: -demands[g]):
                self._place_partial(counts, g, int(demands[g]), blk, work)
            if whole:
                remaining = budget - (time.perf_counter() - t0)
                fine_budget = max(
                    _MIN_STAGE_BUDGET, remaining / max(1, len(active) - bi)
                )
                sub, _, _, method = _solve_counts(
                    group_size, len(whole), work, alpha, beta, integral,
                    fine_budget, use_greedy_bound=greedy,
                )
                fine_methods.append(method)
                for gi, g in enumerate(whole):
                    for ji, j in enumerate(blk):
                        counts[g, j] += int(sub[gi, ji])
        return counts, {
            "n_blocks": len(blocks),
            "blocks_touched": len(active),
            "coarse_method": coarse_method,
            "fine_methods": fine_methods,
        }

    @staticmethod
    def _place_partial(
        counts: np.ndarray, g: int, need: int, blk: list[int], work: np.ndarray
    ) -> None:
        """Place ``need`` nodes of seam group ``g`` into the block: whole
        into the tightest sufficient minipod (best-fit, preserves large
        pods for whole groups), else split largest-first."""
        fit = [i for i in range(len(blk)) if work[i] >= need]
        if fit:
            i = min(fit, key=lambda i: (work[i], i))
            counts[g, blk[i]] += need
            work[i] -= need
            return
        for i in np.argsort(-work):
            if need == 0:
                return
            take = int(min(work[i], need))
            if take <= 0:
                continue
            counts[g, blk[i]] += take
            work[i] -= take
            need -= take
        if need:
            raise Infeasible(
                f"block {blk[0]}-{blk[-1]} lacks capacity for seam group {g}"
            )

    # ------------------------------------------------------------ warm start
    def _try_repair(self, request) -> "ScheduleResult | None":
        """Local repair of ``prev_placement`` around ``dirty_nodes``.

        Returns a result (method ``"hier-warm"``) or None to fall through
        to the cold path.  Replacement preference mirrors
        :class:`FailureManager`: same domain (spread unchanged), then a
        domain the affected groups already span (nearest by fabric hop
        distance first), then any free node.
        """
        from repro.core.scheduler import ScheduleResult

        prev = request.prev_placement
        if prev is None or prev.comm.shape != request.comm.shape:
            return None
        dirty = set(request.dirty_nodes)
        max_dirty = int(request.options.get("repair_max_dirty", 8))
        placed = set(prev.node_ids())
        affected = sorted(dirty & placed)
        if len(affected) > max_dirty:
            return None

        t0 = time.perf_counter()
        assignment = prev.assignment.copy()
        repaired: list[tuple[int, int]] = []
        taken: set[int] = set()
        with request.masked_cluster() as cluster:
            for node in affected:
                repl = self._find_replacement(
                    cluster, assignment, node, dirty | placed | taken
                )
                if repl is None:
                    return None  # cold solve handles it
                r, c = np.argwhere(assignment == node)[0]
                assignment[r, c] = repl
                taken.add(repl)
                repaired.append((int(node), int(repl)))
            placement = Placement(
                comm=request.comm, assignment=assignment, cluster=cluster
            )
        dp_s, pp_s = max_spreads(placement)
        alpha, beta = request.alpha, request.resolved_beta()
        return ScheduleResult(
            placement=placement,
            objective=alpha * dp_s + beta * pp_s,
            dp_spread=dp_s,
            pp_spread=pp_s,
            solve_seconds=time.perf_counter() - t0,
            method="hier-warm",
            stats={
                "warm_start": True,
                "repaired": repaired,
                "cache": dict(self.cache.stats.as_dict(), hit=False),
            },
        )

    @staticmethod
    def _find_replacement(
        cluster: Cluster,
        assignment: np.ndarray,
        node: int,
        unusable: set[int],
    ) -> Optional[int]:
        pod = cluster.domain_of(node)

        def usable(p: int) -> list[int]:
            return [n for n in cluster.free_in_domain(p) if n not in unusable]

        local = usable(pod)
        if local:
            return local[0]
        r, c = np.argwhere(assignment == node)[0]
        group_pods = {
            cluster.domain_of(int(n))
            for n in np.concatenate([assignment[r, :], assignment[:, c]])
            if int(n) != node
        }
        # Prefer domains the groups already span, then nearest by fabric
        # hop distance (uniform on clos, so the order there is unchanged).
        candidates = sorted(
            (p for p in range(cluster.n_domains) if p != pod),
            key=lambda p: (p not in group_pods, cluster.domain_distance(pod, p), p),
        )
        for p in candidates:
            avail = usable(p)
            if avail:
                return avail[0]
        return None
