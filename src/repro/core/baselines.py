"""Baseline scheduling algorithms benchmarked against Arnold (paper §7.1).

1. ``best_fit``    -- assigns nodes to the minipods with the least remaining
                      resources (classic VM-consolidation best-fit [32]).
2. ``random_fit``  -- balanced random assignment across minipods [44].
3. ``gpu_packing`` -- SOTA GPU-cluster packing [43, 45], modified (as in the
                      paper) to pack multi-GPU jobs into as few minipods as
                      possible (largest-free-first consolidation).
4. ``topo_aware``  -- topology-aware placement [2]: hierarchical static
                      mapping by dual recursive bi-partitioning [10], with
                      the graph bi-partitioning done by the
                      Fiduccia-Mattheyses linear-time heuristic [11].

Each baseline returns a :class:`Placement` so all algorithms are scored by
the same Eq. 2 weighted-spread metric.

The public functions here are **deprecated** thin shims over the unified
scheduler registry (:mod:`repro.core.scheduler`); the ``_``-prefixed
implementations are what the registry wraps.  Use
``get_scheduler(name).schedule(request)`` -- the only supported entry point
(DESIGN.md §2.4) -- which adds excluded/reserved-node masking and a uniform
result type; the shims emit :class:`DeprecationWarning` on every call.
"""

from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.comm_matrix import CommMatrix
from repro.core.mip import Infeasible
from repro.core.spread import Placement
from repro.core.topology import Cluster


def _materialize(comm: CommMatrix, cluster: Cluster, node_order: list[int]) -> Placement:
    """Assign matrix cells (row-major rank order) to an ordered node list."""
    if len(node_order) != comm.n_cells:
        raise Infeasible(
            f"need {comm.n_cells} nodes, got {len(node_order)}"
        )
    assignment = np.array(node_order, dtype=int).reshape(comm.shape)
    return Placement(comm=comm, assignment=assignment, cluster=cluster)


def _take_from_pods(cluster: Cluster, pod_order: list[int], n: int) -> list[int]:
    out: list[int] = []
    for j in pod_order:
        if len(out) >= n:
            break
        out.extend(cluster.free_in_domain(j)[: n - len(out)])
    if len(out) < n:
        raise Infeasible(f"cluster has only {len(out)} free nodes, need {n}")
    return out


# ---------------------------------------------------------------------------
def _best_fit(comm: CommMatrix, cluster: Cluster) -> Placement:
    """Fill domains with the *least* remaining free nodes first."""
    free = cluster.free_capacities()
    pods = sorted(
        (j for j in range(cluster.n_domains) if free[j] > 0),
        key=lambda j: (free[j], j),
    )
    return _materialize(comm, cluster, _take_from_pods(cluster, pods, comm.n_cells))


def _gpu_packing(comm: CommMatrix, cluster: Cluster) -> Placement:
    """Consolidate the job into the fewest domains (largest-free-first)."""
    free = cluster.free_capacities()
    pods = sorted(
        (j for j in range(cluster.n_domains) if free[j] > 0),
        key=lambda j: (-free[j], j),
    )
    return _materialize(comm, cluster, _take_from_pods(cluster, pods, comm.n_cells))


def _random_fit(comm: CommMatrix, cluster: Cluster, rng: np.random.Generator) -> Placement:
    """Balanced random assignment: nodes drawn round-robin from domains in
    random order, so the load lands evenly (fair) but topology-blind."""
    free_lists = {
        j: list(rng.permutation(cluster.free_in_domain(j)))
        for j in range(cluster.n_domains)
        if cluster.free_in_domain(j)
    }
    order: list[int] = []
    pods = list(free_lists)
    while len(order) < comm.n_cells and pods:
        pods = [j for j in pods if free_lists[j]]
        if not pods:
            break
        for j in rng.permutation(pods):
            if len(order) >= comm.n_cells:
                break
            if free_lists[j]:
                order.append(int(free_lists[j].pop()))
    return _materialize(comm, cluster, order)


# ---------------------------------------------------------------------------
# Topo-aware: dual recursive bi-partitioning with Fiduccia-Mattheyses.
# ---------------------------------------------------------------------------

def _job_graph(comm: CommMatrix) -> dict[int, dict[int, float]]:
    """Weighted adjacency of matrix cells.

    PP groups are chains (send-recv to adjacent stages, weight v_p); DP
    groups are rings (ring all-gather/reduce-scatter between consecutive
    replicas, weight v_d).  Matches the paper's job-graph analogy to the
    communication matrix.
    """
    n_rows, n_cols = comm.shape
    ids = comm.cell_ids()
    adj: dict[int, dict[int, float]] = {int(i): {} for i in ids.ravel()}

    def link(a: int, b: int, w: float):
        adj[a][b] = adj[a].get(b, 0.0) + w
        adj[b][a] = adj[b].get(a, 0.0) + w

    for r in range(n_rows):
        for c in range(n_cols - 1):
            link(int(ids[r, c]), int(ids[r, c + 1]), comm.v_p)
    for c in range(n_cols):
        for r in range(n_rows):
            link(int(ids[r, c]), int(ids[(r + 1) % n_rows, c]), comm.v_d / max(n_rows, 1))
    return adj


def _fm_bipartition(
    adj: dict[int, dict[int, float]],
    vertices: list[int],
    size_a: int,
    seed: int = 0,
    passes: int = 4,
) -> tuple[list[int], list[int]]:
    """Fiduccia-Mattheyses min-cut bi-partition into parts of exact sizes
    (size_a, len(vertices)-size_a).

    Pair-swap FM variant (keeps both part sizes fixed, since minipod
    capacities are hard constraints): each pass greedily performs the
    best-gain swap of one unlocked vertex from each side, locks both, and at
    the end of the pass rolls back to the best cumulative-gain prefix.
    """
    del seed  # deterministic initial split; randomness not needed
    verts = list(vertices)
    side = {v: (i >= size_a) for i, v in enumerate(verts)}  # False=A, True=B

    def gain(v: int, cur: dict[int, bool]) -> float:
        # Gain of moving v to the other side: external - internal edge weight.
        g = 0.0
        for u, w in adj[v].items():
            if u in cur:
                g += w if cur[u] != cur[v] else -w
        return g

    for _ in range(passes):
        locked: set[int] = set()
        cur = dict(side)
        history: list[tuple[float, int, int]] = []  # (cum_gain, va, vb)
        cum = 0.0
        while True:
            part_a = [v for v in verts if not cur[v] and v not in locked]
            part_b = [v for v in verts if cur[v] and v not in locked]
            if not part_a or not part_b:
                break
            ga = {v: gain(v, cur) for v in part_a}
            gb = {v: gain(v, cur) for v in part_b}
            va = max(part_a, key=lambda v: (ga[v], -v))
            vb = max(part_b, key=lambda v: (gb[v], -v))
            cum += ga[va] + gb[vb] - 2 * adj[va].get(vb, 0.0)
            cur[va], cur[vb] = True, False
            locked.update((va, vb))
            history.append((cum, va, vb))
        if not history:
            break
        gains = [h[0] for h in history]
        best_i = int(np.argmax(gains))
        if gains[best_i] <= 1e-9:
            break  # no improving prefix; partition converged
        for _, va, vb in history[: best_i + 1]:
            side[va], side[vb] = True, False
    part_a = [v for v in verts if not side[v]]
    part_b = [v for v in verts if side[v]]
    assert len(part_a) == size_a, (len(part_a), size_a)
    return part_a, part_b


def _topo_aware(comm: CommMatrix, cluster: Cluster) -> Placement:
    """Hierarchical static mapping: recursively bi-partition the physical
    graph (fabric domains, by free capacity) and map the job graph onto the
    two halves with an FM min-cut of matching sizes [2, 10, 11].

    The physical split delegates to the fabric's bisection structure
    (:meth:`Cluster.partition_domains`): id-order halves on ``clos``
    (identical to the pre-fabric behaviour), axis-aligned slabs on
    ``torus``, group-coherent halves on ``dragonfly``."""
    adj = _job_graph(comm)
    free = cluster.free_capacities()
    pods = [j for j in range(cluster.n_domains) if free[j] > 0]
    if sum(free[j] for j in pods) < comm.n_cells:
        raise Infeasible("not enough free nodes")

    cell_to_pod: dict[int, int] = {}

    def recurse(pod_set: list[int], cells: list[int]):
        if not cells:
            return
        if len(pod_set) == 1:
            for v in cells:
                cell_to_pod[v] = pod_set[0]
            return
        pods_a, pods_b = cluster.partition_domains(pod_set)
        cap_a = sum(free[j] for j in pods_a)
        size_a = min(cap_a, len(cells))
        # ensure part B fits too
        cap_b = sum(free[j] for j in pods_b)
        size_a = max(size_a, len(cells) - cap_b)
        part_a, part_b = _fm_bipartition(adj, cells, size_a)
        recurse(pods_a, part_a)
        recurse(pods_b, part_b)

    recurse(pods, [int(v) for v in comm.cell_ids().ravel()])

    # materialize: rank-contiguous node assignment inside each pod
    n_rows, n_cols = comm.shape
    assignment = np.full((n_rows, n_cols), -1, dtype=int)
    for j in pods:
        cells = sorted(v for v, p in cell_to_pod.items() if p == j)
        nodes = cluster.free_in_domain(j)
        for v, nid in zip(cells, nodes):
            assignment[v // n_cols, v % n_cols] = nid
    return Placement(comm=comm, assignment=assignment, cluster=cluster)


# ---------------------------------------------------------------------------
# Public entry points: deprecated thin shims over the scheduler registry.
# The registry (get_scheduler, DESIGN.md §2.4) is the only supported entry
# point; these remain for backward compatibility and warn on every call.
# ---------------------------------------------------------------------------

def _via_registry(name: str, comm: CommMatrix, cluster: Cluster, **req_kw) -> Placement:
    import warnings

    from repro.core.scheduler import ScheduleRequest, get_scheduler

    warnings.warn(
        f"the module-level baseline functions are deprecated; use "
        f'get_scheduler("{name}").schedule(ScheduleRequest(...)) instead',
        DeprecationWarning,
        stacklevel=3,
    )
    request = ScheduleRequest(comm=comm, cluster=cluster, **req_kw)
    return get_scheduler(name).schedule(request).placement


def best_fit(comm: CommMatrix, cluster: Cluster) -> Placement:
    """Deprecated shim for ``get_scheduler("best-fit")``; see
    :func:`_best_fit` for the algorithm."""
    return _via_registry("best-fit", comm, cluster)


def gpu_packing(comm: CommMatrix, cluster: Cluster) -> Placement:
    """Deprecated shim for ``get_scheduler("gpu-packing")``; see
    :func:`_gpu_packing` for the algorithm."""
    return _via_registry("gpu-packing", comm, cluster)


def random_fit(
    comm: CommMatrix,
    cluster: Cluster,
    seed: int = 0,
    rng: Optional[np.random.Generator] = None,
) -> Placement:
    """Deprecated shim for ``get_scheduler("random-fit")``; reproducible via
    ``seed`` or an explicit ``rng`` (``rng`` wins when both are given)."""
    return _via_registry("random-fit", comm, cluster, seed=seed, rng=rng)


def topo_aware(comm: CommMatrix, cluster: Cluster, seed: int = 0) -> Placement:
    """Deprecated shim for ``get_scheduler("topo-aware")``; ``seed`` is
    accepted for API compatibility but the FM partitioning is deterministic."""
    del seed
    return _via_registry("topo-aware", comm, cluster)


ALL_BASELINES = {
    "best-fit": best_fit,
    "random-fit": random_fit,
    "gpu-packing": gpu_packing,
    "topo-aware": topo_aware,
}
