"""Trace-driven cluster simulator (paper §6/§7.1, Appendix H).

Replays a job trace against a :class:`Cluster` under a pluggable queue
policy, recording the Appendix-H time series (allocation rate, retention
rate, queuing delay) and -- for LPJs -- the end-to-end throughput estimated
by the calibrated network model, which is how Figure 9 is reproduced
without 9600 physical GPUs.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Callable, Optional

import numpy as np

from repro.core.comm_matrix import CommMatrix
from repro.core.netmodel import NetModel, fabric_net_model, simulate_step_time
from repro.core.queue import Job, QueuePolicy
from repro.core.spread import Placement, max_hop_diameters, max_spreads


@dataclasses.dataclass
class TimePoint:
    t: float
    allocation_rate: float
    retention_rate: float
    queued: int


@dataclasses.dataclass
class SimResult:
    series: list[TimePoint]
    queue_delays: dict[int, float]
    preempted_at_lpj: int
    manual_preemptions: int    # non-preemptable squatters at LPJ arrival
    lpj_nodes: list[int]
    failed_nodes: list[int] = dataclasses.field(default_factory=list)
    lpj_replans: int = 0       # warm re-solves triggered by failure churn

    def mean_alloc(self) -> float:
        return float(np.mean([p.allocation_rate for p in self.series]))


class TraceSimulator:
    """Discrete-event replay: arrivals + completions + scheduling ticks."""

    def __init__(self, policy: QueuePolicy, tick: float = 60.0):
        self.policy = policy
        self.tick = tick

    def run(
        self,
        jobs: list[Job],
        t_end: float,
        lpj_plan: Optional[tuple] = None,
        plan_at: float = 0.0,
        failures: Optional[list[tuple[float, int]]] = None,
    ) -> SimResult:
        """Replay ``jobs``; if ``lpj_plan=(comm, arrival, alpha, unit)`` is
        given, the LPJ is planned at ``plan_at`` and admitted at arrival.
        An optional fifth element selects the scheduling policy for this
        LPJ -- a registry name, chain spec ("mip,topo-aware"), or Scheduler
        instance -- overriding the queue policy's default.

        ``failures`` is a list of ``(time, node_id)`` hardware failures.
        A failed node is quarantined (taken out of the free pool for good);
        if it belongs to a still-pending LPJ reservation, the plan is
        re-solved through :meth:`QueuePolicy.replan_lpj`, which hands
        warm-start-capable schedulers the previous placement plus the
        dirty set -- the churn path of DESIGN.md §8.2."""
        events: list[tuple[float, int, str, object]] = []
        eid = 0

        def push(t, kind, payload):
            nonlocal eid
            heapq.heappush(events, (t, eid, kind, payload))
            eid += 1

        for j in jobs:
            push(j.arrival, "arrive", j)
        t = 0.0
        while t <= t_end:
            push(t, "tick", None)
            t += self.tick
        if lpj_plan is not None:
            comm, arrival, alpha, unit, *rest = lpj_plan
            scheduler = rest[0] if rest else None
            push(plan_at, "plan", (comm, arrival, alpha, unit, scheduler))
            push(arrival, "lpj", None)
        for ft, node in failures or []:
            push(ft, "fail", node)

        series: list[TimePoint] = []
        delays: dict[int, float] = {}
        submit_time: dict[int, float] = {}
        preempted_n = 0
        manual_n = 0
        lpj_nodes: list[int] = []
        failed: list[int] = []
        replans = 0

        while events:
            t, _, kind, payload = heapq.heappop(events)
            if t > t_end:
                break
            if kind == "arrive":
                job = payload
                submit_time[job.job_id] = t
                self.policy.submit(job)
            elif kind == "plan":
                comm, arrival, alpha, unit, scheduler = payload
                self.policy.plan_lpj(comm, arrival, alpha, unit=unit,
                                     scheduler=scheduler)
            elif kind == "fail":
                node = int(payload)
                if self.policy.cluster.is_free(node):
                    self.policy.cluster.allocate([node])  # quarantine
                failed.append(node)
                lpj = self.policy.lpj
                if (
                    lpj is not None and lpj.result is not None
                    and t < lpj.arrival
                    and node in lpj.reserved_nodes
                ):
                    self.policy.replan_lpj(dirty_nodes=frozenset(failed))
                    replans += 1
            elif kind == "lpj":
                lpj_nodes, preempted = self.policy.admit_lpj(t)
                preempted_n = len(preempted)
                manual_n = sum(1 for j in preempted if not j.preemptable)
            elif kind == "tick":
                started = self.policy.schedule_tick(t)
                for job in started:
                    delays[job.job_id] = t - submit_time[job.job_id]
                    push(t + job.duration, "finish", job)
                series.append(
                    TimePoint(
                        t=t,
                        allocation_rate=self.policy.allocation_rate(),
                        retention_rate=self.policy.retention_rate(),
                        queued=len(self.policy.queue),
                    )
                )
            elif kind == "finish":
                job = payload
                if job.job_id in self.policy.running:
                    self.policy.complete(job.job_id)
        return SimResult(
            series=series,
            queue_delays=delays,
            preempted_at_lpj=preempted_n,
            manual_preemptions=manual_n,
            lpj_nodes=lpj_nodes,
            failed_nodes=failed,
            lpj_replans=replans,
        )


# ---------------------------------------------------------------------------
# LPJ throughput simulation (Figures 5 / 9 reproduction path).
# ---------------------------------------------------------------------------

def throughput_of_placement(
    placement: Placement,
    net: Optional[NetModel] = None,
    steps: int = 1,
    seed: int = 0,
    **step_kw,
) -> dict:
    """Simulated tokens/sec of an LPJ under a placement.

    The spread and hop diameter of the slowest DP and PP group feed the
    calibrated BusBw model; throughput = tokens per step / simulated step
    time.  ``net`` defaults to the placement's per-fabric model
    (:func:`repro.core.netmodel.fabric_net_model`) -- on ``clos`` that is
    output-identical to the legacy :class:`NetModel`.
    """
    net = net or fabric_net_model(placement.cluster.fabric)
    rng = np.random.default_rng(seed)
    comm = placement.comm
    dp_s, pp_s = max_spreads(placement)
    dp_h, pp_h = max_hop_diameters(placement)
    times = [
        simulate_step_time(comm, dp_s, pp_s, net=net, rng=rng,
                           dp_hops=dp_h, pp_hops_diameter=pp_h, **step_kw)
        for _ in range(steps)
    ]
    model = comm.job.model
    tokens = model.global_batch * model.seq_len
    mean_t = float(np.mean([b.total for b in times]))
    return {
        "dp_spread": dp_s,
        "pp_spread": pp_s,
        "dp_hop_diameter": dp_h,
        "pp_hop_diameter": pp_h,
        "fabric": placement.cluster.fabric.kind,
        "step_time_s": mean_t,
        "tokens_per_s": tokens / mean_t,
        "comm_fraction": float(np.mean([b.comm_fraction() for b in times])),
        "breakdown": times[-1],
    }


def poisson_trace(
    n_jobs: int,
    mean_interarrival: float,
    mean_duration: float,
    max_nodes: int,
    seed: int = 0,
    preemptable_frac: float = 0.15,
) -> list[Job]:
    """Synthetic open-loop trace with lognormal durations (cluster traces
    are heavy-tailed [3])."""
    rng = np.random.default_rng(seed)
    t = 0.0
    jobs = []
    for i in range(n_jobs):
        t += float(rng.exponential(mean_interarrival))
        size = int(2 ** rng.integers(0, int(np.log2(max(max_nodes, 2)))))
        dur = float(rng.lognormal(np.log(mean_duration), 0.8))
        meta = dict(
            n_gpus=size * 8,
            n_cpus=size * 64,
            mem_gb=size * 512,
            n_drives=int(rng.integers(0, 4)),
            department=int(rng.integers(0, 6)),
            priority=0,
            hour_of_day=int(t / 3600) % 24,
            user_avg_jct=dur * float(rng.uniform(0.7, 1.3)),
        )
        jobs.append(
            Job(
                job_id=i,
                n_nodes=size,
                arrival=t,
                duration=dur,
                metadata=meta,
                preemptable=bool(rng.random() < preemptable_frac),
            )
        )
    return jobs
