"""Workload representation: the communication matrix (paper §5.1, Eq. 1)
and the analytical communication-volume model (Appendix C, Eq. 11-13).

An LLM pre-training job (LPJ) with ``n_gpus`` accelerators and hybrid
parallelism degrees (TP, PP) is represented as a matrix of *nodes* where

    DP   = n_gpus / TP / PP          (Eq. 1)
    #row = DP / (8 / TP)             rows    -> PP groups (pipeline chains)
    #col = PP                        columns -> DP groups (replica sets)

Every matrix cell is one physical node (8 GPUs) and carries the vector
``[v_w, v_d, v_p]`` = per-GPU weight bytes, DP volume, PP volume, computed
from the analytical model; an optional ``v_e`` (expert-parallel all-to-all
volume) extends the paper's model to MoE EP traffic (DESIGN.md §4).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.topology import GPUS_PER_NODE


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """GPT-style model hyper-parameters used by the analytical volume model.

    Notation follows Appendix C / Megatron: vocabulary ``V``, global batch
    ``gb``, micro batch ``mb``, sequence length ``s``, hidden ``h``, layers
    ``l``.  MoE models add ``n_experts``/``top_k``/``d_expert`` (per-expert
    FFN hidden size); dense models leave them at 0.
    """

    name: str
    hidden: int
    layers: int
    vocab: int
    seq_len: int
    global_batch: int
    micro_batch: int = 1
    # Dense FFN hidden (0 for pure-MoE FFN stacks).
    d_ff: int = 0
    # MoE extension.
    n_experts: int = 0
    top_k: int = 0
    d_expert: int = 0
    bytes_per_element: int = 2  # bf16 activations / grads on the wire

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0


@dataclasses.dataclass(frozen=True)
class JobSpec:
    """User-facing job request: #GPUs + parallelism degrees + model."""

    n_gpus: int
    tp: int
    pp: int
    model: ModelSpec
    gpu_type: str = "H800"

    def __post_init__(self):
        if self.n_gpus % (self.tp * self.pp):
            raise ValueError(
                f"n_gpus={self.n_gpus} not divisible by tp*pp={self.tp * self.pp}"
            )
        if self.n_gpus % GPUS_PER_NODE:
            raise ValueError("jobs are node-granular (8 GPUs per node)")
        if GPUS_PER_NODE % self.tp:
            raise ValueError("TP must divide the node size (TP stays intra-node, §2)")

    @property
    def dp(self) -> int:
        return self.n_gpus // self.tp // self.pp

    @property
    def n_nodes(self) -> int:
        return self.n_gpus // GPUS_PER_NODE

    @property
    def n_microbatches(self) -> int:
        m = self.model
        return max(1, m.global_batch // (m.micro_batch * self.dp))


# --------------------------------------------------------------------------
# Appendix C: analytical communication volumes (bytes per GPU per step).
# --------------------------------------------------------------------------

def dp_volume_bytes(job: JobSpec) -> float:
    """Eq. 12: DP-group volume per GPU (parameter/gradient synchronization).

    ``h*(V+s)`` covers embedding + position tables; the per-layer term
    ``4h^2+2h`` is attention (QKVO) and ``8h^2+7h`` the FFN + norms, divided
    by PP because each GPU only synchronizes its own pipeline stage.  For MoE
    models the FFN term is replaced by the expert parameters hosted per GPU
    (experts are sharded EP-wise inside the TP/"model" dimension, so the
    per-GPU share is n_experts/EP expert FFNs).
    """
    m = job.model
    emb = m.hidden * (m.vocab + m.seq_len)
    attn = 4 * m.hidden**2 + 2 * m.hidden
    if m.is_moe:
        ep = min(m.n_experts, GPUS_PER_NODE // job.tp * job.n_nodes // job.pp)
        ep = max(1, min(ep, job.dp * job.tp))  # experts sharded across the stage
        ffn = 3 * m.hidden * m.d_expert * m.n_experts / ep + 7 * m.hidden
    else:
        d_ff = m.d_ff if m.d_ff else 4 * m.hidden
        # 8h^2 + 7h with d_ff = 4h in the paper's GPT; generalize to 2*h*d_ff.
        ffn = 2 * m.hidden * d_ff + 7 * m.hidden
    elements = emb + (m.layers / job.pp) * (attn + ffn)
    return float(elements) * m.bytes_per_element


def pp_volume_bytes(job: JobSpec) -> float:
    """Eq. 13: PP-group volume per GPU per microbatch pair (fwd + bwd)."""
    m = job.model
    return float(2 * m.micro_batch * m.seq_len * m.hidden) * m.bytes_per_element


def ep_volume_bytes(job: JobSpec) -> float:
    """Beyond-paper: expert-parallel all-to-all volume per GPU per microbatch.

    Each token is routed to ``top_k`` experts: dispatch + combine moves
    ``2 * top_k * tokens * h`` elements through the all-to-all.
    """
    m = job.model
    if not m.is_moe:
        return 0.0
    tokens_per_gpu = m.micro_batch * m.seq_len
    return float(2 * m.top_k * tokens_per_gpu * m.hidden) * m.bytes_per_element


def weight_bytes_per_gpu(job: JobSpec) -> float:
    """v_w: parameter bytes hosted per GPU (stage params / TP)."""
    return dp_volume_bytes(job) / job.tp


# --------------------------------------------------------------------------
# Eq. 1: the communication matrix.
# --------------------------------------------------------------------------

@dataclasses.dataclass
class CommMatrix:
    """Node-level communication matrix for one LPJ.

    ``shape = (n_rows, n_cols)``; ``cell_volumes`` is the per-GPU
    ``[v_w, v_d, v_p]`` vector shared by all cells (groups are homogeneous,
    §5.2 "domain-specific simplification").  ``rows`` index PP groups,
    ``cols`` index DP groups.
    """

    job: JobSpec
    n_rows: int
    n_cols: int
    v_w: float
    v_d: float
    v_p: float
    v_e: float = 0.0

    @property
    def n_cells(self) -> int:
        return self.n_rows * self.n_cols

    @property
    def shape(self) -> tuple[int, int]:
        return (self.n_rows, self.n_cols)

    def cell_ids(self) -> np.ndarray:
        """Row-major cell identifiers, shape (n_rows, n_cols)."""
        return np.arange(self.n_cells).reshape(self.n_rows, self.n_cols)

    # Fingerprint ratios used for affinity lookup (§5.2).
    def ratios(self) -> tuple[float, float]:
        m = self.job.model
        r1 = (m.micro_batch * self.v_w) / max(self.v_d + self.v_p, 1e-9)
        r2 = self.v_d / max(self.v_p, 1e-9)
        return r1, r2


def build_comm_matrix(job: JobSpec) -> CommMatrix:
    """Eq. 1 + Appendix C: derive the matrix shape and volume annotations."""
    nodes_per_pp_group_stage = GPUS_PER_NODE // job.tp  # DP replicas per node
    if job.dp % nodes_per_pp_group_stage:
        raise ValueError(
            f"DP={job.dp} must be divisible by 8/TP={nodes_per_pp_group_stage} "
            "for node-granular rows (Eq. 1)"
        )
    n_rows = job.dp // nodes_per_pp_group_stage
    n_cols = job.pp
    return CommMatrix(
        job=job,
        n_rows=n_rows,
        n_cols=n_cols,
        v_w=weight_bytes_per_gpu(job),
        v_d=dp_volume_bytes(job),
        v_p=pp_volume_bytes(job),
        v_e=ep_volume_bytes(job),
    )
