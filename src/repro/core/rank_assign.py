"""Logical-rank to physical-device mapping (paper §6: "modify [the training
framework] to ensure that communication groups follow the placement").

Arnold's output is consumed by the training framework as a permutation:
logical rank ``(pp_stage, dp_rank, tp_rank)`` -> physical GPU.  On the JAX
target this permutation is applied to ``jax.devices()`` *before* building
the mesh, so pjit's communication groups (mesh axes) land on the aligned
physical blocks the MIP chose (see ``repro.launch.mesh``).
"""

from __future__ import annotations

import numpy as np

from repro.core.spread import Placement
from repro.core.topology import GPUS_PER_NODE


def node_rank_order(placement: Placement) -> list[int]:
    """Node ids ordered by matrix rank (row-major: PP-inner, like Megatron's
    default order with pipeline innermost across nodes)."""
    return [int(n) for n in placement.assignment.ravel()]


def logical_to_physical_gpus(
    placement: Placement, tp: int, gpus_per_node: int = GPUS_PER_NODE
) -> np.ndarray:
    """Array ``phys[pp, dp, tp]`` of physical GPU ids.

    Matrix cell (r, c) hosts ``gpus_per_node // tp`` DP replicas of PP stage
    ``c``; within a node, TP ranks map to consecutive local GPUs (TP stays on
    NVLink/intra-node links, §2).
    """
    n_rows, n_cols = placement.comm.shape
    reps = gpus_per_node // tp  # DP replicas per node
    dp = n_rows * reps
    out = np.empty((n_cols, dp, tp), dtype=int)
    for r in range(n_rows):
        for c in range(n_cols):
            node = int(placement.assignment[r, c])
            base = node * gpus_per_node
            for k in range(reps):
                for t in range(tp):
                    out[c, r * reps + k, t] = base + k * tp + t
    return out


def device_permutation(
    placement: Placement, tp: int, gpus_per_node: int = GPUS_PER_NODE
) -> list[int]:
    """Flat physical-GPU permutation in logical order (pp, dp, tp) -- feed to
    ``jax.make_mesh(..., devices=devices[perm])``-style constructors."""
    return [int(g) for g in logical_to_physical_gpus(placement, tp, gpus_per_node).ravel()]
