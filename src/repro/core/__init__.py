"""Arnold: topology-aware communication alignment for LLM pre-training.

The paper's primary contribution, as a composable library:

* :mod:`repro.core.topology`     -- cluster model over a pluggable fabric
  (:mod:`repro.topo`: clos / rail-only / torus / dragonfly)
* :mod:`repro.core.comm_matrix`  -- workload representation (Eq. 1, App. C)
* :mod:`repro.core.spread`       -- spread metric + Eq. 2 objective
* :mod:`repro.core.mip`          -- the MILP scheduler (Eq. 4-10)
* :mod:`repro.core.baselines`    -- best-fit / random-fit / gpu-packing / topo-aware
* :mod:`repro.core.scheduler`    -- unified Scheduler API: request/result
  contract, policy registry, fallback chains
* :mod:`repro.core.hierarchical` -- "hier" scale tier: block decomposition,
  warm-start re-solve, placement cache (sub-second at 10k nodes)
* :mod:`repro.core.placement_cache` -- counts-matrix cache for recurring
  job shapes
* :mod:`repro.core.affinity`     -- characterization DB -> (alpha, beta)
* :mod:`repro.core.queue`        -- Algorithm 1 reservation policy
* :mod:`repro.core.jct`          -- GBM job-completion-time predictor
* :mod:`repro.core.simulator`    -- trace-driven simulator
* :mod:`repro.core.netmodel`     -- calibrated BusBw / step-time model
* :mod:`repro.core.failures`     -- backup-node repair, straggler mitigation
* :mod:`repro.core.rank_assign`  -- placement -> device permutation
"""

from repro.core.affinity import CharacterizationDB, CharRecord
from repro.core.baselines import ALL_BASELINES, best_fit, gpu_packing, random_fit, topo_aware
from repro.core.characterize import characterize, characterize_sweep
from repro.core.comm_matrix import (
    CommMatrix,
    JobSpec,
    ModelSpec,
    build_comm_matrix,
    dp_volume_bytes,
    ep_volume_bytes,
    pp_volume_bytes,
)
from repro.core.failures import FailureManager
from repro.core.hierarchical import HierarchicalScheduler
from repro.core.jct import JCTPredictor, synthetic_trace
from repro.core.mip import Infeasible, MipResult, schedule_mip
from repro.core.placement_cache import CacheStats, PlacementCache
from repro.core.netmodel import (
    ClosNetModel,
    DragonflyNetModel,
    FabricNetModel,
    NetModel,
    NetModelConfig,
    RailOnlyNetModel,
    TorusNetModel,
    fabric_net_model,
    register_fabric_net_model,
    simulate_step_time,
)
from repro.core.queue import Job, QueuePolicy
from repro.core.rank_assign import device_permutation, logical_to_physical_gpus
from repro.core.scheduler import (
    FallbackChain,
    ScheduleRequest,
    ScheduleResult,
    Scheduler,
    get_scheduler,
    list_schedulers,
    register_scheduler,
)
from repro.core.simulator import TraceSimulator, poisson_trace, throughput_of_placement
from repro.core.spread import Placement, max_hop_diameters, max_spreads, weighted_spread
from repro.core.topology import Cluster, Domain, Minipod, Node
from repro.topo import Fabric, get_fabric, list_fabrics, register_fabric

__all__ = [name for name in dir() if not name.startswith("_")]
