"""Resource management: queue policy with LPJ reservation (paper §5.3,
Algorithm 1, Appendices G/H).

Once an LPJ is *planned* (its arrival time announced), the scheduler solves
the placement MIP immediately and **reserves** the chosen nodes.  From then
on incoming jobs are:

* scheduled normally if they fit outside the reserved zone,
* opportunistically back-filled *into* the reserved zone iff their predicted
  JCT (GBM, Appendix G) completes before the LPJ arrives,
* scheduled anyway if preemptable (evicted on LPJ arrival),
* otherwise delayed to the next scheduling interval.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Callable, Optional

import numpy as np

from repro.core.comm_matrix import CommMatrix
from repro.core.jct import JCTPredictor
from repro.core.scheduler import (
    ScheduleRequest,
    ScheduleResult,
    Scheduler,
    get_scheduler,
)
from repro.core.topology import Cluster


@dataclasses.dataclass
class Job:
    """A generic (non-LPJ) cluster job."""

    job_id: int
    n_nodes: int
    arrival: float
    duration: float          # true duration (simulator ground truth)
    metadata: dict = dataclasses.field(default_factory=dict)
    priority: int = 0
    preemptable: bool = False
    # runtime state
    start: Optional[float] = None
    nodes: list[int] = dataclasses.field(default_factory=list)
    in_reserved_zone: bool = False

    def sort_key(self) -> tuple:
        return (-self.priority, self.arrival, self.job_id)


@dataclasses.dataclass
class PlannedLPJ:
    comm: CommMatrix
    arrival: float
    alpha: float
    beta: float
    unit: str = "pp"
    result: Optional[ScheduleResult] = None

    @property
    def reserved_nodes(self) -> set[int]:
        if self.result is None:
            return set()
        return set(self.result.placement.node_ids())


class QueuePolicy:
    """Algorithm 1: reservation-aware queue management."""

    def __init__(
        self,
        cluster: Cluster,
        jct_predictor: Optional[JCTPredictor] = None,
        interval: float = 60.0,
        reserve: bool = True,
        use_jct: bool = True,
        scheduler: "str | Scheduler" = "mip",
    ):
        self.cluster = cluster
        self.jct = jct_predictor
        self.interval = interval
        self.reserve = reserve
        self.use_jct = use_jct
        self.scheduler = get_scheduler(scheduler)
        self.lpj: Optional[PlannedLPJ] = None
        self.queue: list[tuple[tuple, Job]] = []  # heap by sort_key
        self.running: dict[int, Job] = {}

    # ------------------------------------------------------------------ LPJ
    def plan_lpj(self, comm: CommMatrix, arrival: float, alpha: float,
                 beta: float | None = None, unit: str = "pp",
                 scheduler: "str | Scheduler | None" = None) -> ScheduleResult:
        """Solve the placement now and reserve the nodes for the imminent LPJ.

        The policy's scheduler (or the per-call ``scheduler`` override --
        a registry name, instance, or fallback chain) runs against the
        cluster as if empty-of-preemptables: reservation semantics are
        strong (unlike the best-effort reserving-and-packing baseline,
        Appendix H)."""
        beta = 1.0 - alpha if beta is None else beta
        sched = self.scheduler if scheduler is None else get_scheduler(scheduler)
        snapshot = self.cluster.snapshot_free()
        occupied_by_jobs = [
            n for j in self.running.values() for n in j.nodes
        ]
        # Plan over free + currently-running-but-finite capacity: the paper
        # plans hours ahead, so occupied nodes will have drained by arrival.
        self.cluster.release(occupied_by_jobs)
        try:
            result = sched.schedule(ScheduleRequest(
                comm=comm, cluster=self.cluster, alpha=alpha, beta=beta,
                unit=unit,
            ))
        finally:
            self.cluster.allocate(occupied_by_jobs)
            assert self.cluster.snapshot_free() == snapshot
        self.lpj = PlannedLPJ(
            comm=comm, arrival=arrival, alpha=alpha, beta=beta, unit=unit,
            result=result,
        )
        return result

    def replan_lpj(self, dirty_nodes, scheduler: "str | Scheduler | None" = None
                   ) -> ScheduleResult:
        """Re-solve the planned LPJ placement after node churn.

        ``dirty_nodes`` are the nodes that changed (failed/drained) since
        :meth:`plan_lpj`; they are excluded from the new solve and passed
        as the warm-start hint together with the previous placement, so a
        warm-start-capable scheduler ("hier") repairs the reservation
        locally instead of re-solving from scratch.  Updates the stored
        plan (and thereby the reserved zone) in place.
        """
        if self.lpj is None or self.lpj.result is None:
            raise ValueError("no planned LPJ to re-plan")
        lpj = self.lpj
        dirty = frozenset(dirty_nodes)
        sched = self.scheduler if scheduler is None else get_scheduler(scheduler)
        snapshot = self.cluster.snapshot_free()
        occupied_by_jobs = [n for j in self.running.values() for n in j.nodes]
        self.cluster.release(occupied_by_jobs)
        try:
            result = sched.schedule(ScheduleRequest(
                comm=lpj.comm, cluster=self.cluster, alpha=lpj.alpha,
                beta=lpj.beta, unit=lpj.unit, excluded_nodes=dirty,
                prev_placement=lpj.result.placement, dirty_nodes=dirty,
            ))
        finally:
            self.cluster.allocate(occupied_by_jobs)
            assert self.cluster.snapshot_free() == snapshot
        lpj.result = result
        return result

    def reserved_nodes(self) -> set[int]:
        if not self.reserve or self.lpj is None:
            return set()
        return self.lpj.reserved_nodes

    # ---------------------------------------------------------------- queue
    def submit(self, job: Job) -> None:
        heapq.heappush(self.queue, (job.sort_key(), job))

    def _allocate_outside(self, job: Job, now: float) -> bool:
        reserved = self.reserved_nodes() if (self.lpj and now < self.lpj.arrival) else set()
        free = [n for n in self.cluster.snapshot_free() if n not in reserved]
        if len(free) < job.n_nodes:
            return False
        nodes = sorted(free)[: job.n_nodes]
        self.cluster.allocate(nodes)
        job.nodes, job.start, job.in_reserved_zone = nodes, now, False
        self.running[job.job_id] = job
        return True

    def _allocate_anywhere(self, job: Job, now: float, reserved_ok: bool) -> bool:
        free = sorted(self.cluster.snapshot_free())
        if len(free) < job.n_nodes:
            return False
        reserved = self.reserved_nodes()
        # Prefer non-reserved nodes even when the zone is allowed.
        free.sort(key=lambda n: (n in reserved, n))
        nodes = free[: job.n_nodes]
        if not reserved_ok and any(n in reserved for n in nodes):
            return False
        self.cluster.allocate(nodes)
        job.nodes, job.start = nodes, now
        job.in_reserved_zone = any(n in reserved for n in nodes)
        self.running[job.job_id] = job
        return True

    def _predicted_done(self, job: Job, now: float) -> float:
        if self.jct is not None and self.use_jct and job.metadata:
            return now + float(self.jct.predict_seconds([job.metadata])[0])
        return now + job.duration  # oracle fallback

    def schedule_tick(self, now: float) -> list[Job]:
        """One pass of Algorithm 1 over the queue; returns jobs started."""
        started: list[Job] = []
        delayed: list[tuple[tuple, Job]] = []
        while self.queue:
            _, job = heapq.heappop(self.queue)
            lpj_pending = self.lpj is not None and now < self.lpj.arrival
            if job.preemptable:
                ok = self._allocate_anywhere(job, now, reserved_ok=True)
            elif self._allocate_outside(job, now):
                ok = True
            elif (
                lpj_pending
                and self.use_jct
                and self._predicted_done(job, now) < self.lpj.arrival
                and self._allocate_anywhere(job, now, reserved_ok=True)
            ):
                ok = True
            elif not lpj_pending and self._allocate_anywhere(job, now, reserved_ok=True):
                ok = True
            else:
                ok = False
            if ok:
                started.append(job)
            else:
                delayed.append((job.sort_key(), job))
        for item in delayed:
            heapq.heappush(self.queue, item)
        return started

    def complete(self, job_id: int) -> None:
        job = self.running.pop(job_id)
        self.cluster.release(job.nodes)
        job.nodes = []

    def admit_lpj(self, now: float) -> tuple[list[int], list[Job]]:
        """LPJ arrival: preempt whatever still occupies the reserved zone and
        hand over its nodes.  Returns (lpj nodes, preempted jobs)."""
        assert self.lpj is not None and self.lpj.result is not None
        nodes = self.lpj.result.placement.node_ids()
        preempted = []
        for job in list(self.running.values()):
            if any(n in set(nodes) for n in job.nodes):
                preempted.append(job)
                self.complete(job.job_id)
        self.cluster.allocate(nodes)
        return nodes, preempted

    # -------------------------------------------------------------- metrics
    def allocation_rate(self) -> float:
        """Fraction of cluster nodes running some job (Appendix H)."""
        busy = self.cluster.n_nodes - self.cluster.n_free
        return busy / self.cluster.n_nodes

    def retention_rate(self) -> float:
        """Fraction of the LPJ's *planned* nodes occupied by non-preemptable
        jobs -- these would need manual preemption at LPJ arrival (Appendix
        H).  Measured against the plan regardless of whether reservation is
        enforced, so the no-reservation baseline is comparable."""
        if self.lpj is None:
            return 0.0
        planned = self.lpj.reserved_nodes
        if not planned:
            return 0.0
        occupied = {
            n for j in self.running.values() if not j.preemptable for n in j.nodes
        }
        return len(planned & occupied) / len(planned)
