"""Fault tolerance at the scheduling layer (paper Appendix B + beyond).

The paper notes (Limitations) that on hardware failure the optimal placement
changes, but a full MIP re-solve + migration is too expensive, and suggests
reserving *backup nodes per communication group* that run preemptable jobs
until promoted.  This module implements that proposal, plus:

* **local repair**: when no backup is available in the failed node's
  minipod, re-solve a restricted MIP for just the affected scheduling-unit
  group against current free capacity (orders of magnitude smaller than the
  full problem),
* **straggler mitigation**: a slow node (detected from step-time telemetry)
  is treated as a soft failure and swapped with a backup.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.mip import Infeasible
from repro.core.spread import Placement, max_spreads
from repro.core.topology import Cluster


@dataclasses.dataclass
class RepairEvent:
    failed_node: int
    replacement: int
    kind: str           # "backup" | "local" | "cross-pod"
    dp_spread_after: int
    pp_spread_after: int


class FailureManager:
    """Maintains per-minipod backup nodes for a running LPJ and repairs the
    placement on node failure / straggling without a full re-solve."""

    def __init__(
        self,
        placement: Placement,
        cluster: Cluster,
        backup_frac: float = 0.05,
        seed: int = 0,
    ):
        self.placement = placement
        self.cluster = cluster
        self.rng = np.random.default_rng(seed)
        self.events: list[RepairEvent] = []
        self.dead: set[int] = set()   # failed nodes never return to the pool
        # Reserve ceil(backup_frac * pod_usage) free nodes in every minipod
        # that the job occupies.
        self.backups: dict[int, list[int]] = {}
        pods_used = {}
        for nid in placement.node_ids():
            pod = cluster.nodes[nid].minipod
            pods_used[pod] = pods_used.get(pod, 0) + 1
        for pod, used in pods_used.items():
            want = max(1, int(np.ceil(backup_frac * used)))
            free = cluster.free_in_minipod(pod)[:want]
            if free:
                cluster.allocate(free)
                self.backups[pod] = list(free)

    def backup_count(self) -> int:
        return sum(len(v) for v in self.backups.values())

    def _replace(self, node_id: int, replacement: int, kind: str) -> RepairEvent:
        a = self.placement.assignment
        r, c = np.argwhere(a == node_id)[0]
        a[r, c] = replacement
        dp_s, pp_s = max_spreads(self.placement)
        ev = RepairEvent(
            failed_node=node_id,
            replacement=replacement,
            kind=kind,
            dp_spread_after=dp_s,
            pp_spread_after=pp_s,
        )
        self.events.append(ev)
        return ev

    def on_failure(self, node_id: int) -> RepairEvent:
        """Replace a failed node.  Preference order: (1) same-minipod backup
        (spread unchanged), (2) same-minipod free node, (3) any free node in
        a minipod the group already spans, (4) any free node (cross-pod)."""
        if node_id not in self.placement.node_ids():
            raise ValueError(f"node {node_id} not part of the placement")
        pod = self.cluster.nodes[node_id].minipod

        self.dead.add(node_id)  # quarantined: stays allocated, never reused
        # (1) promoted backup
        if self.backups.get(pod):
            repl = self.backups[pod].pop(0)
            return self._replace(node_id, repl, "backup")
        # (2) local free node
        free_local = [n for n in self.cluster.free_in_minipod(pod) if n not in self.dead]
        if free_local:
            repl = free_local[0]
            self.cluster.allocate([repl])
            return self._replace(node_id, repl, "local")
        # (3)/(4) cross-pod: prefer pods already hosting the affected groups
        a = self.placement.assignment
        r, c = np.argwhere(a == node_id)[0]
        pods_of_groups = {
            self.cluster.nodes[int(n)].minipod
            for n in np.concatenate([a[r, :], a[:, c]])
            if int(n) != node_id
        }
        def usable(p):
            return [n for n in self.cluster.free_in_minipod(p) if n not in self.dead]

        candidates = sorted(
            (p for p in range(self.cluster.n_minipods) if usable(p)),
            key=lambda p: (p not in pods_of_groups, p),
        )
        if not candidates:
            raise Infeasible("no free node anywhere to repair the placement")
        repl = usable(candidates[0])[0]
        self.cluster.allocate([repl])
        return self._replace(node_id, repl, "cross-pod")

    def on_straggler(self, node_id: int) -> Optional[RepairEvent]:
        """Swap a persistently slow node with a same-pod backup if one
        exists; otherwise leave it (a cross-pod move could cost more than the
        straggler does)."""
        pod = self.cluster.nodes[node_id].minipod
        if self.backups.get(pod):
            repl = self.backups[pod].pop(0)
            self.cluster.release([node_id])  # straggler is healthy: reusable
            return self._replace(node_id, repl, "backup")
        return None
