"""Arnold's MILP scheduling algorithm (paper §5.2, Eq. 4-10).

The exact objective (Eq. 2) has a discrete distance term that off-the-shelf
solvers handle poorly, so the paper coarsens the scheduling unit to a whole
communication group (groups are homogeneous and gang-synchronous) and solves
the bin-packing-like MILP

    MIN   alpha * sum_j y_j + beta * T
    s.t.  forall i: sum_j s_ij <= T                (max spread)
          forall j: sum_i p_ij <= c_j * y_j        (capacity)
          forall i: sum_j p_ij  = 1                (allocation)
          forall i,j: p_ij <= s_ij                 (minipod selection)
          y_j, s_ij in {0,1},  p_ij in [0,1]

with ``i`` ranging over scheduling-unit groups (rows = PP groups by default,
Table 1) and ``j`` over minipods; ``c_j`` is the minipod's free capacity
normalized by the group size.  We solve with scipy's HiGHS MILP (the paper
uses SCIP [4]); ``integral_nodes=True`` additionally makes the node counts
``n_ij = p_ij * group_size`` integral, which removes the rounding repair the
continuous (paper-faithful) relaxation needs.

After solving, nodes inside each minipod are assigned **contiguous rank
indices** (§5.2 last paragraph) so that intra-minipod communication also
stays rack-local.
"""

from __future__ import annotations

import contextlib
import dataclasses
import os
import time

import numpy as np
import scipy.sparse as sp
from scipy.optimize import Bounds, LinearConstraint, milp

from repro.core.comm_matrix import CommMatrix
from repro.core.spread import Placement
from repro.core.topology import Cluster


@dataclasses.dataclass
class MipResult:
    placement: Placement
    objective: float
    n_pods_used: int
    max_unit_spread: int
    solve_seconds: float
    counts: np.ndarray  # (n_groups, n_minipods) node counts
    method: str = "milp"  # "milp" | "greedy-proven-optimal" | "greedy-incumbent"


class Infeasible(RuntimeError):
    pass


@contextlib.contextmanager
def _silence_stdout():
    """HiGHS prints C-level diagnostics scipy cannot suppress; mute fd 1+2."""
    saved = [os.dup(1), os.dup(2)]
    try:
        devnull = os.open(os.devnull, os.O_WRONLY)
        os.dup2(devnull, 1)
        os.dup2(devnull, 2)
        os.close(devnull)
        yield
    finally:
        os.dup2(saved[0], 1)
        os.dup2(saved[1], 2)
        os.close(saved[0])
        os.close(saved[1])


# ---------------------------------------------------------------------------
# Greedy bounding: the scheduling-unit groups are *identical* (homogeneous +
# gang-synchronous, §5.2), which creates heavy symmetry in the MILP.  Before
# invoking the solver we compute (a) a provable lower bound on the objective
# and (b) greedy candidate solutions; when a candidate meets the bound the
# MILP is skipped entirely, otherwise the candidate caps the solver's work
# as an incumbent compared against the time-limited MILP result.
# ---------------------------------------------------------------------------

def _objective_lower_bound(group_size: int, m: int, free: np.ndarray, alpha: float, beta: float) -> float:
    """Provable objective lower bound, split by the max-spread value T:

    * T = 1: every group whole -> pods provide ``floor(c_j/G)`` slots, and the
      minimum pod count q1 takes pods with the most slots (exact).
    * T >= 2: pods only need raw capacity -> q_min pods by capacity.

    The bound is the smaller branch; T >= 3 is dominated by the T = 2 branch.
    """
    caps = np.sort(free)[::-1]
    need = group_size * m
    q_min = int(np.searchsorted(np.cumsum(caps), need) + 1)
    slots = np.sort(free // group_size)[::-1]
    cum_slots = np.cumsum(slots)
    if cum_slots[-1] >= m:
        q1 = int(np.searchsorted(cum_slots, m) + 1)
        lb_t1 = alpha * max(q1, q_min) + beta * 1.0
    else:
        lb_t1 = np.inf  # T=1 infeasible
    lb_t2 = alpha * q_min + beta * 2.0
    return float(min(lb_t1, lb_t2))


def _greedy_whole(group_size: int, m: int, free: np.ndarray) -> np.ndarray | None:
    """T=1 candidate: pack whole groups into pods with the most slots."""
    slots = (free // group_size).astype(int)
    order = np.argsort(-slots)
    counts = np.zeros((m, len(free)), dtype=int)
    g = 0
    for j in order:
        for _ in range(int(slots[j])):
            if g >= m:
                return counts
            counts[g, j] = group_size
            g += 1
    return None  # not enough whole-group slots for T=1


def _greedy_sequential(group_size: int, m: int, free: np.ndarray, n_pods: int) -> np.ndarray | None:
    """Contiguous fill of the ``n_pods`` largest pods (descending capacity);
    groups may straddle pod boundaries (spread > 1 at the seams)."""
    order = np.argsort(-free)[:n_pods]
    if free[order].sum() < group_size * m:
        return None
    counts = np.zeros((m, len(free)), dtype=int)
    g, need = 0, group_size
    for j in order:
        avail = int(free[j])
        while avail > 0 and g < m:
            take = min(avail, need)
            counts[g, j] += take
            avail -= take
            need -= take
            if need == 0:
                g, need = g + 1, group_size
    return counts if g >= m else None


def _counts_objective(counts: np.ndarray, alpha: float, beta: float) -> float:
    pods_used = int((counts.sum(axis=0) > 0).sum())
    t = int(max((row > 0).sum() for row in counts))
    return alpha * pods_used + beta * t


def _greedy_candidates(
    group_size: int, m: int, free: np.ndarray, alpha: float, beta: float
) -> tuple[np.ndarray | None, float]:
    best, best_obj = None, np.inf
    cands = [_greedy_whole(group_size, m, free)]
    caps = np.sort(free)[::-1]
    q_min = int(np.searchsorted(np.cumsum(caps), group_size * m) + 1)
    for q in range(q_min, min(len(free), q_min + 4) + 1):
        cands.append(_greedy_sequential(group_size, m, free, q))
    for c in cands:
        if c is None:
            continue
        obj = _counts_objective(c, alpha, beta)
        if obj < best_obj:
            best, best_obj = c, obj
    return best, best_obj


def _solve_counts(
    group_size: int,
    n_groups: int,
    free: np.ndarray,
    alpha: float,
    beta: float,
    integral_nodes: bool,
    time_limit: float,
    use_greedy_bound: bool = True,
) -> tuple[np.ndarray, float, float, str]:
    """Solve the scheduling problem; return (counts, objective, seconds, method).

    Fast path: identical groups make the MILP highly symmetric, so we first
    build greedy candidates and a provable lower bound; if they meet, the
    solver is skipped ("greedy-proven-optimal").  Otherwise the MILP runs
    under ``time_limit`` and the better of (incumbent, MILP) is returned.
    """
    k = len(free)
    m = n_groups
    if free.sum() < group_size * m:
        raise Infeasible(
            f"need {group_size * m} nodes, only {int(free.sum())} free"
        )

    t_start = time.perf_counter()
    incumbent, incumbent_obj = (None, np.inf)
    if use_greedy_bound:
        incumbent, incumbent_obj = _greedy_candidates(group_size, m, free, alpha, beta)
        lb = _objective_lower_bound(group_size, m, free, alpha, beta)
        if incumbent is not None and incumbent_obj <= lb + 1e-9:
            return incumbent, incumbent_obj, time.perf_counter() - t_start, "greedy-proven-optimal"

    # Variable layout: [ y_0..y_{k-1} | s_00..s_{m-1,k-1} | p_00.. | T ]
    n_y, n_s, n_p = k, m * k, m * k
    n_var = n_y + n_s + n_p + 1
    iy = lambda j: j
    is_ = lambda i, j: n_y + i * k + j
    ip = lambda i, j: n_y + n_s + i * k + j
    iT = n_var - 1

    c = np.zeros(n_var)
    c[:n_y] = alpha
    c[iT] = beta

    rows, cols, vals, lo, hi = [], [], [], [], []
    r = 0

    def add(entries, lb, ub):
        nonlocal r
        for col, val in entries:
            rows.append(r)
            cols.append(col)
            vals.append(val)
        lo.append(lb)
        hi.append(ub)
        r += 1

    # (Eq. 5) max spread: sum_j s_ij - T <= 0
    for i in range(m):
        add([(is_(i, j), 1.0) for j in range(k)] + [(iT, -1.0)], -np.inf, 0.0)
    # (Eq. 6) capacity: sum_i p_ij - c_j y_j <= 0,   c_j = free_j / group_size
    for j in range(k):
        cj = free[j] / group_size
        add([(ip(i, j), 1.0) for i in range(m)] + [(iy(j), -cj)], -np.inf, 0.0)
    # (Eq. 7) allocation: sum_j p_ij = 1
    for i in range(m):
        add([(ip(i, j), 1.0) for j in range(k)], 1.0, 1.0)
    # (Eq. 8) selection: p_ij - s_ij <= 0
    for i in range(m):
        for j in range(k):
            add([(ip(i, j), 1.0), (is_(i, j), -1.0)], -np.inf, 0.0)

    A = sp.csr_matrix(
        (vals, (rows, cols)), shape=(r, n_var)
    )
    constraints = LinearConstraint(A, lb=np.array(lo), ub=np.array(hi))

    lb = np.zeros(n_var)
    ub = np.ones(n_var)
    ub[iT] = k
    integrality = np.zeros(n_var)
    integrality[: n_y + n_s] = 1  # y, s binary
    if integral_nodes:
        # Make p_ij integral in units of 1/group_size: substitute q = p*gs.
        # scipy's milp has no scaling hook, so emulate via semi-integer trick:
        # declare p integral after scaling the column. Simplest robust path:
        # solve with p continuous first, then branch manually is overkill --
        # instead we scale the p-columns by declaring integrality on
        # n_ij = group_size * p_ij via a change of variable done by scaling
        # bounds and constraint coefficients.
        pass  # handled below by variable scaling

    if integral_nodes:
        # Change of variable: p'_ij = group_size * p_ij (integer node count).
        # Scale: objective has no p terms; constraints touching p get /gs.
        A = A.tolil()
        for i in range(m):
            for j in range(k):
                col = ip(i, j)
                A[:, col] = A[:, col] / group_size
        A = A.tocsr()
        constraints = LinearConstraint(A, lb=np.array(lo), ub=np.array(hi))
        ub[n_y + n_s : n_y + n_s + n_p] = group_size
        integrality[n_y + n_s : n_y + n_s + n_p] = 1

    with _silence_stdout():
        res = milp(
            c=c,
            constraints=constraints,
            integrality=integrality,
            bounds=Bounds(lb, ub),
            options={"time_limit": time_limit},
        )
    dt = time.perf_counter() - t_start
    if res.x is None:
        if incumbent is not None:
            return incumbent, incumbent_obj, dt, "greedy-incumbent"
        raise Infeasible(f"MILP failed: status={res.status} {res.message}")

    p = res.x[n_y + n_s : n_y + n_s + n_p].reshape(m, k)
    if integral_nodes:
        counts = np.rint(p).astype(int)
    else:
        counts = _round_counts(p, group_size, free)
    milp_obj = _counts_objective(counts, alpha, beta)
    if incumbent is not None and incumbent_obj < milp_obj:
        return incumbent, incumbent_obj, dt, "greedy-incumbent"
    return counts, milp_obj, dt, "milp"


def _round_counts(p: np.ndarray, group_size: int, free: np.ndarray) -> np.ndarray:
    """Largest-remainder rounding of fractional p to node counts, then a
    capacity repair pass (paper-faithful continuous relaxation needs this)."""
    m, k = p.shape
    counts = np.zeros((m, k), dtype=int)
    for i in range(m):
        raw = p[i] * group_size
        base = np.floor(raw).astype(int)
        rem = group_size - base.sum()
        order = np.argsort(-(raw - base))
        base[order[:rem]] += 1
        counts[i] = base
    # Repair: pod over capacity -> move surplus cells to pods with slack,
    # preferring pods the group already uses (keeps spread unchanged).
    used = counts.sum(axis=0)
    for j in range(k):
        while used[j] > free[j]:
            i = int(np.argmax(counts[:, j]))
            # candidate target pods, prefer ones group i already occupies
            slack = free - used
            cand = np.argsort(-(slack + 1000 * (counts[i] > 0)))
            moved = False
            for j2 in cand:
                if j2 != j and slack[j2] > 0:
                    counts[i, j] -= 1
                    counts[i, j2] += 1
                    used[j] -= 1
                    used[j2] += 1
                    moved = True
                    break
            if not moved:
                raise Infeasible("rounding repair could not satisfy capacity")
    return counts


def _counts_to_placement(
    comm: CommMatrix,
    cluster: Cluster,
    counts: np.ndarray,
    unit: str,
) -> Placement:
    """Materialize node assignments from per-(group, pod) counts.

    Columns are distributed to a row's pods in ascending pod-id order, so
    rows with identical pod allocations get identical column->pod maps and
    their DP groups align (this is the cross-group alignment the objective's
    ``sum_j y_j`` term buys).  Inside every minipod, cells are sorted by
    row-major rank and mapped to ascending free node ids -> contiguous ranks.
    """
    n_rows, n_cols = comm.shape
    if unit == "pp":
        groups = [(("row", r), n_cols) for r in range(n_rows)]
    else:
        groups = [(("col", c), n_rows) for c in range(n_cols)]

    # cell -> pod
    cell_pod = np.full((n_rows, n_cols), -1, dtype=int)
    for gi, ((kind, idx), size) in enumerate(groups):
        order = np.argsort(np.where(counts[gi] > 0, np.arange(counts.shape[1]), 1 << 30))
        pos = 0
        for j in order:
            c = int(counts[gi, j])
            if c == 0:
                continue
            for t in range(pos, pos + c):
                if kind == "row":
                    cell_pod[idx, t] = j
                else:
                    cell_pod[t, idx] = j
            pos += c
        assert pos == size

    # pod -> nodes, rank-contiguous
    assignment = np.full((n_rows, n_cols), -1, dtype=int)
    for j in range(counts.shape[1]):
        cells = [
            (r * n_cols + c, r, c)
            for r in range(n_rows)
            for c in range(n_cols)
            if cell_pod[r, c] == j
        ]
        if not cells:
            continue
        cells.sort()
        free_nodes = cluster.free_in_domain(j)
        if len(free_nodes) < len(cells):
            raise Infeasible(f"domain {j} lacks free nodes at materialization")
        for (rank, r, c), nid in zip(cells, free_nodes):
            assignment[r, c] = nid
    return Placement(comm=comm, assignment=assignment, cluster=cluster)


def schedule_mip(
    comm: CommMatrix,
    cluster: Cluster,
    alpha: float,
    beta: float | None = None,
    unit: str = "pp",
    integral_nodes: bool = True,
    time_limit: float = 10.0,
    use_greedy_bound: bool = True,
) -> MipResult:
    """Arnold's scheduler: solve Eq. 4-10 and materialize the placement.

    ``unit`` picks the scheduling-unit group: ``"pp"`` treats each PP group
    (matrix row) as one unit -- minimizing T consolidates PP chains while
    ``alpha * sum_j y_j`` consolidates the orthogonal DP groups; ``"dp"``
    swaps the roles (used when DP communication dominates, Appendix E).

    .. deprecated::
        Thin shim over the unified scheduler registry, kept only for
        backward compatibility: use
        ``get_scheduler("mip").schedule(ScheduleRequest(...))`` (see
        :mod:`repro.core.scheduler` and DESIGN.md §2.4), which this
        delegates to before repackaging as a :class:`MipResult`.
    """
    import warnings

    from repro.core.scheduler import ScheduleRequest, get_scheduler

    warnings.warn(
        "schedule_mip() is deprecated; use "
        'get_scheduler("mip").schedule(ScheduleRequest(...)) instead',
        DeprecationWarning,
        stacklevel=2,
    )

    request = ScheduleRequest(
        comm=comm,
        cluster=cluster,
        alpha=alpha,
        beta=beta,
        unit=unit,
        time_budget=time_limit,
        options={
            "integral_nodes": integral_nodes,
            "use_greedy_bound": use_greedy_bound,
        },
    )
    res = get_scheduler("mip").schedule(request)
    return MipResult(
        placement=res.placement,
        objective=res.objective,
        n_pods_used=res.stats["n_pods_used"],
        max_unit_spread=res.stats["max_unit_spread"],
        solve_seconds=res.solve_seconds,
        counts=res.stats["counts"],
        method=res.method,
    )
