"""Physical data-center topology model (paper §2, Fig. 2b).

The cluster is a three-tier CLOS: nodes -> leaf switches (s0, one per rack)
-> spine switches (s1, one *minipod* per spine group) -> core switches.
The paper's characterization (§4) shows training performance is dominated by
the *minipod spread* of communication groups and is insensitive to
intra-minipod topology (<= 0.3% variation), so the scheduling topology is
modeled at minipod granularity, with racks retained for rank ordering.

On the TPU target the "minipod" maps to an ICI pod / contiguous device block
(see DESIGN.md §3); the same abstractions drive the mesh device permutation.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

GPUS_PER_NODE = 8


@dataclasses.dataclass(frozen=True)
class Node:
    """A compute node: 8 accelerators under one NIC/leaf switch."""

    node_id: int
    minipod: int
    rack: int
    gpus: int = GPUS_PER_NODE


@dataclasses.dataclass
class Minipod:
    """Nodes under one spine switch (s1)."""

    pod_id: int
    node_ids: list[int]

    @property
    def capacity(self) -> int:
        return len(self.node_ids)


class Cluster:
    """Three-tier CLOS cluster at minipod granularity.

    Tracks free/busy nodes; scheduling algorithms allocate from here.
    """

    def __init__(self, nodes_per_minipod: Sequence[int], nodes_per_rack: int = 8):
        self.minipods: list[Minipod] = []
        self.nodes: dict[int, Node] = {}
        nid = 0
        for pod_id, n in enumerate(nodes_per_minipod):
            ids = []
            for i in range(n):
                rack = i // nodes_per_rack
                self.nodes[nid] = Node(node_id=nid, minipod=pod_id, rack=rack)
                ids.append(nid)
                nid += 1
            self.minipods.append(Minipod(pod_id=pod_id, node_ids=ids))
        self._free: set[int] = set(self.nodes)

    # ------------------------------------------------------------------ state
    @property
    def n_minipods(self) -> int:
        return len(self.minipods)

    @property
    def n_nodes(self) -> int:
        return len(self.nodes)

    @property
    def n_free(self) -> int:
        return len(self._free)

    def free_in_minipod(self, pod_id: int) -> list[int]:
        return sorted(n for n in self.minipods[pod_id].node_ids if n in self._free)

    def free_capacities(self) -> list[int]:
        return [len(self.free_in_minipod(p.pod_id)) for p in self.minipods]

    def free_signature(self, quantum: int = 1) -> tuple[int, ...]:
        """Hashable free-capacity fingerprint: per-minipod free counts
        rounded *down* to a multiple of ``quantum`` nodes.

        This is the canonical way to compare free-pool states (placement
        cache keys, benchmark workload fingerprints) -- rounding down means
        two states sharing a signature differ by less than ``quantum``
        nodes in any minipod, so a placement solved for one is usually
        still near-optimal for the other (DESIGN.md §8.3).
        """
        if quantum < 1:
            raise ValueError(f"quantum must be >= 1, got {quantum}")
        return tuple(
            (len(self.free_in_minipod(p.pod_id)) // quantum) * quantum
            for p in self.minipods
        )

    def is_free(self, node_id: int) -> bool:
        return node_id in self._free

    # ------------------------------------------------------------- transitions
    def allocate(self, node_ids: Iterable[int]) -> None:
        ids = list(node_ids)
        missing = [n for n in ids if n not in self._free]
        if missing:
            raise ValueError(f"nodes not free: {missing}")
        self._free -= set(ids)

    def release(self, node_ids: Iterable[int]) -> None:
        for n in node_ids:
            if n not in self.nodes:
                raise ValueError(f"unknown node {n}")
            self._free.add(n)

    def snapshot_free(self) -> set[int]:
        return set(self._free)

    # ---------------------------------------------------------------- factories
    @classmethod
    def uniform(cls, n_minipods: int, nodes_per_minipod: int, **kw) -> "Cluster":
        return cls([nodes_per_minipod] * n_minipods, **kw)

    @classmethod
    def paper_setting(cls, which: str) -> "Cluster":
        """Benchmark topologies from Table 1 (subsets of the production cluster).

        ``{x}, {y}`` = x minipods, y nodes total.  Nodes are spread as evenly
        as possible across minipods (the paper does not publish the per-pod
        distribution of its subsets).
        """
        spec = {"i": (3, 18), "ii": (5, 438), "iii": (11, 1019)}[which]
        pods, total = spec
        base, rem = divmod(total, pods)
        return cls([base + (1 if i < rem else 0) for i in range(pods)])

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        caps = self.free_capacities()
        return f"Cluster(minipods={self.n_minipods}, nodes={self.n_nodes}, free={caps})"
