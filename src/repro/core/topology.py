"""Physical data-center topology model (paper §2, Fig. 2b; DESIGN.md §9).

The paper's cluster is a three-tier CLOS: nodes -> leaf switches (s0, one
per rack) -> spine switches (s1, one *minipod* per spine group) -> core
switches.  Its characterization (§4) shows training performance is
dominated by the *minipod spread* of communication groups and is
insensitive to intra-minipod topology (<= 0.3% variation), so scheduling
is modeled at minipod granularity.

Since the fabric subsystem (:mod:`repro.topo`), the minipod is one
instance of the general concept: a :class:`Cluster` is built from any
:class:`repro.topo.Fabric`, whose *locality domains* play the minipod
role for every scheduler, the spread metric, and the network model.  The
legacy ``Cluster(nodes_per_minipod=...)`` constructor is the ``clos``
shorthand and behaves identically to the pre-fabric code (parity asserted
in tests/test_topo.py).

On the TPU target the "minipod" maps to an ICI pod / contiguous device
block (see DESIGN.md §3); the ``torus`` fabric models that interconnect
directly.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Optional, Sequence

import numpy as np

from repro.topo import ClosFabric, Fabric

GPUS_PER_NODE = 8


@dataclasses.dataclass(frozen=True)
class Node:
    """A compute node: 8 accelerators under one NIC/leaf switch.

    ``minipod`` is the node's fabric *domain* id (the historical name is
    kept; prefer :meth:`Cluster.domain_of` / ``Cluster.fabric`` for
    fabric-generic code).
    """

    node_id: int
    minipod: int
    rack: int
    gpus: int = GPUS_PER_NODE


@dataclasses.dataclass
class Minipod:
    """One fabric locality domain (a spine group on ``clos``, a rail group
    on ``rail-only``, a torus vertex, a dragonfly router)."""

    pod_id: int
    node_ids: list[int]

    @property
    def capacity(self) -> int:
        return len(self.node_ids)


#: fabric-generic alias for :class:`Minipod`.
Domain = Minipod


class Cluster:
    """A cluster of nodes over a pluggable fabric, at domain granularity.

    Tracks free/busy nodes; scheduling algorithms allocate from here.
    ``Cluster(nodes_per_minipod=[...])`` is the ``clos`` shorthand
    (builds a :class:`repro.topo.ClosFabric`); any other fabric comes in
    through ``Cluster(fabric=...)`` / :meth:`from_fabric`.
    """

    def __init__(
        self,
        nodes_per_minipod: Optional[Sequence[int]] = None,
        nodes_per_rack: int = 8,
        *,
        fabric: Optional[Fabric] = None,
    ):
        if (nodes_per_minipod is None) == (fabric is None):
            raise ValueError(
                "pass exactly one of nodes_per_minipod (clos shorthand) "
                "or fabric"
            )
        if fabric is None:
            fabric = ClosFabric(nodes_per_minipod, nodes_per_rack=nodes_per_rack)
        self.fabric: Fabric = fabric
        #: node id -> domain id, precomputed for hot-path vectorized lookups
        #: (see Placement.domain_of in core/spread.py).
        self.domain_index: np.ndarray = np.asarray(fabric.domain_index(), dtype=int)

        self.minipods: list[Minipod] = []
        self.nodes: dict[int, Node] = {}
        rack_size = getattr(fabric, "nodes_per_rack", nodes_per_rack)
        for pod_id in range(fabric.n_domains):
            ids = fabric.domain_nodes(pod_id)
            for slot, nid in enumerate(ids):
                self.nodes[nid] = Node(
                    node_id=nid, minipod=pod_id, rack=slot // rack_size
                )
            self.minipods.append(Minipod(pod_id=pod_id, node_ids=list(ids)))
        self._free: set[int] = set(self.nodes)

    # ------------------------------------------------------------------ state
    @property
    def n_minipods(self) -> int:
        """Number of fabric domains (historical name; same as
        :attr:`n_domains`)."""
        return len(self.minipods)

    @property
    def n_domains(self) -> int:
        return len(self.minipods)

    @property
    def n_nodes(self) -> int:
        return len(self.nodes)

    @property
    def n_free(self) -> int:
        return len(self._free)

    def domain_of(self, node_id: int) -> int:
        """Fabric domain id of a node (O(1) array lookup)."""
        return int(self.domain_index[node_id])

    def free_in_minipod(self, pod_id: int) -> list[int]:
        """Free nodes of one domain.  Historical ``clos`` name for
        :meth:`free_in_domain`; both work on every fabric."""
        return sorted(n for n in self.minipods[pod_id].node_ids if n in self._free)

    #: fabric-generic alias (the supported name for new code).
    free_in_domain = free_in_minipod

    def free_capacities(self) -> list[int]:
        return [len(self.free_in_domain(p.pod_id)) for p in self.minipods]

    def free_signature(self, quantum: int = 1) -> tuple[int, ...]:
        """Hashable free-capacity fingerprint: per-domain free counts
        rounded *down* to a multiple of ``quantum`` nodes.

        This is the canonical way to compare free-pool states (placement
        cache keys, benchmark workload fingerprints) -- rounding down means
        two states sharing a signature differ by less than ``quantum``
        nodes in any domain, so a placement solved for one is usually
        still near-optimal for the other (DESIGN.md §8.3).
        """
        if quantum < 1:
            raise ValueError(f"quantum must be >= 1, got {quantum}")
        return tuple(
            (len(self.free_in_domain(p.pod_id)) // quantum) * quantum
            for p in self.minipods
        )

    def is_free(self, node_id: int) -> bool:
        return node_id in self._free

    # ------------------------------------------------------- fabric structure
    def domain_distance(self, a: int, b: int) -> int:
        """Hop distance between two domains (delegates to the fabric)."""
        return self.fabric.domain_distance(a, b)

    def partition_domains(
        self, domains: Sequence[int]
    ) -> tuple[list[int], list[int]]:
        """Fabric-aware bisection of a domain set (recursive mappers)."""
        return self.fabric.partition(domains)

    def scheduling_blocks(self, block_size: int) -> list[list[int]]:
        """Locality-coherent domain blocks for the hierarchical tier."""
        return self.fabric.scheduling_blocks(block_size)

    # ------------------------------------------------------------- transitions
    def allocate(self, node_ids: Iterable[int]) -> None:
        ids = list(node_ids)
        missing = [n for n in ids if n not in self._free]
        if missing:
            raise ValueError(f"nodes not free: {missing}")
        self._free -= set(ids)

    def release(self, node_ids: Iterable[int]) -> None:
        for n in node_ids:
            if n not in self.nodes:
                raise ValueError(f"unknown node {n}")
            self._free.add(n)

    def snapshot_free(self) -> set[int]:
        return set(self._free)

    # ---------------------------------------------------------------- factories
    @classmethod
    def from_fabric(cls, fabric: Fabric) -> "Cluster":
        """Cluster over an explicit fabric instance."""
        return cls(fabric=fabric)

    @classmethod
    def uniform(cls, n_minipods: int, nodes_per_minipod: int, **kw) -> "Cluster":
        return cls([nodes_per_minipod] * n_minipods, **kw)

    @classmethod
    def paper_setting(cls, which: str) -> "Cluster":
        """Benchmark topologies from Table 1 (subsets of the production cluster).

        ``{x}, {y}`` = x minipods, y nodes total.  Nodes are spread as evenly
        as possible across minipods (the paper does not publish the per-pod
        distribution of its subsets).
        """
        spec = {"i": (3, 18), "ii": (5, 438), "iii": (11, 1019)}[which]
        pods, total = spec
        base, rem = divmod(total, pods)
        return cls([base + (1 if i < rem else 0) for i in range(pods)])

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        caps = self.free_capacities()
        return (
            f"Cluster(fabric={self.fabric.kind}, domains={self.n_domains}, "
            f"nodes={self.n_nodes}, free={caps})"
        )
