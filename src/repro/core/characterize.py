"""Automated pre-characterization (paper §4, Fig. 5a -> §5.2 database).

The paper characterizes each (model config, GPU type) by running the job
under DP-aligned / PP-aligned / naive placements and recording the relative
improvements ``(j_dp, j_pp)``, which the online scheduler later converts to
affinity ``alpha = j_dp/(j_dp+j_pp)``.  This module automates that loop in
software: the three placements are constructed exactly as in Figure 3
(DP-aligned = each DP group inside one minipod; PP-aligned = each PP group
inside one minipod; naive = balanced random), their throughput comes from
the calibrated step-time model, and the result is a ready-to-insert
:class:`CharRecord` -- so a new cluster/GPU type can be characterized by
sweeping model configs instead of hand-running NCCL tests.
"""

from __future__ import annotations

from typing import Callable, Optional

from repro.core.affinity import CharRecord
from repro.core.comm_matrix import JobSpec, build_comm_matrix
from repro.core.netmodel import NetModel
from repro.core.scheduler import ScheduleRequest, get_scheduler
from repro.core.simulator import throughput_of_placement
from repro.core.topology import Cluster


def characterize(
    job: JobSpec,
    cluster_factory: Callable[[], Cluster],
    net: Optional[NetModel] = None,
    steps: int = 5,
    **step_kw,
) -> CharRecord:
    """Run the Fig. 5a experiment for one job; return the DB record."""
    net = net or NetModel()
    comm = build_comm_matrix(job)

    mip = get_scheduler("mip")
    # Figure 3b: DP-aligned -- each DP group (column) consolidated.
    dp_aligned = mip.schedule(ScheduleRequest(
        comm=comm, cluster=cluster_factory(), alpha=0.0, beta=1.0, unit="dp",
    )).placement
    # Figure 3c: PP-aligned -- each PP group (row) consolidated.
    pp_aligned = mip.schedule(ScheduleRequest(
        comm=comm, cluster=cluster_factory(), alpha=0.0, beta=1.0, unit="pp",
    )).placement
    # Naive: balanced random (the misaligned Figure 3a situation).
    naive = get_scheduler("random-fit").schedule(ScheduleRequest(
        comm=comm, cluster=cluster_factory(), seed=0,
    )).placement

    t_dp = throughput_of_placement(dp_aligned, net=net, steps=steps, **step_kw)
    t_pp = throughput_of_placement(pp_aligned, net=net, steps=steps, **step_kw)
    t_nv = throughput_of_placement(naive, net=net, steps=steps, **step_kw)

    j_dp = max(0.0, 100.0 * (t_dp["tokens_per_s"] / t_nv["tokens_per_s"] - 1.0))
    j_pp = max(0.0, 100.0 * (t_pp["tokens_per_s"] / t_nv["tokens_per_s"] - 1.0))
    r1, r2 = comm.ratios()
    return CharRecord(
        gpu_type=job.gpu_type,
        model_name=job.model.name,
        r1=r1,
        r2=r2,
        j_dp=j_dp,
        j_pp=j_pp,
        unit="dp" if j_dp > j_pp else "pp",
    )


def characterize_sweep(
    jobs: list[JobSpec],
    cluster_factory: Callable[[], Cluster],
    net: Optional[NetModel] = None,
) -> list[CharRecord]:
    """Pre-characterize a family of jobs (the paper's 'LPJs are scheduled in
    advance and pre-characterized' workflow)."""
    return [characterize(j, cluster_factory, net=net) for j in jobs]
