"""Analytical network performance model calibrated to the paper's
characterization study (§4, Fig. 4; Appendix D), per-fabric since the
:mod:`repro.topo` subsystem (DESIGN.md §9.3).

This container is CPU-only, so the NCCL-test measurements cannot be re-run;
instead we encode the paper's measured behaviour as an alpha-beta
(latency-bandwidth) model with a spread-dependent degradation term:

* BusBw ramps with message size: collectives need >= ~256 MB to saturate,
  send-recv saturates at ~2 MB (Fig. 4a).
* Spanning additional minipods degrades BusBw by up to 17% for collectives
  and up to 70% for P2P send-recv (Fig. 4b/4c).
* Multi-tenant interference adds up to ~5% jitter for jobs spanning many
  minipods (Appendix D).

:class:`NetModel` keeps that CLOS calibration verbatim (its degradation is
a linear ramp in the *number* of minipods spanned, the only locality
signal a uniform-core CLOS has).  The :class:`FabricNetModel` family
generalizes the degradation term: it is derived from the fabric's hop
*distance* structure -- the hop diameter of the placement (or the
fabric's tightest-ball profile when only a spread count is known),
normalized by the fabric diameter -- with per-topology calibration
constants for ``rail-only``, ``torus`` and ``dragonfly``.
:func:`fabric_net_model` picks the right model for a fabric;
``clos`` resolves to :class:`ClosNetModel`, which reproduces
:class:`NetModel` exactly (parity asserted in tests).

The same interface carries the TPU-target constants (DESIGN.md §3) used by
the roofline analysis and the ``torus`` model: 197 TFLOP/s bf16 per chip,
819 GB/s HBM, ~50 GB/s per ICI link.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.topo import Fabric

MB = 1 << 20
GB = 1 << 30

# ----------------------------------------------------------------- hardware
#: TPU v5e-class target constants (per chip), used by roofline analysis.
TPU_PEAK_FLOPS = 197e12      # bf16 FLOP/s
TPU_HBM_BW = 819e9           # bytes/s
TPU_ICI_BW = 50e9            # bytes/s per link

#: H800/IB cluster constants from the paper's environment (§2): 400 Gbps NIC
#: per GPU -> 50 GB/s inter-node per GPU; NVLink intra-node.
IB_PEAK_BUSBW = 50e9         # bytes/s, saturated inter-node BusBw per rank
H800_PEAK_FLOPS = 990e12     # fp16 dense


@dataclasses.dataclass(frozen=True)
class NetModelConfig:
    peak_busbw: float = IB_PEAK_BUSBW
    # Fig. 4a saturation points.
    collective_half_size: float = 48 * MB   # ~256MB to reach >90% of peak
    p2p_half_size: float = 0.25 * MB        # ~2MB saturates
    # Fig. 4b/4c: max degradation at max spread.
    collective_max_degradation: float = 0.17
    p2p_max_degradation: float = 0.70
    max_spread_ref: int = 3                 # spread where max degradation hits
    # Appendix D: co-tenancy interference ceiling.
    interference_max: float = 0.05


class NetModel:
    """BusBw and step-time estimates as a function of message size & spread.

    ``hops`` -- the placement's measured hop diameter
    (:func:`repro.core.spread.max_hop_diameters`) -- is accepted everywhere
    for interface uniformity; this CLOS-calibrated base model ignores it
    (a uniform core has no distance gradient), the
    :class:`FabricNetModel` family uses it.
    """

    def __init__(self, cfg: NetModelConfig | None = None):
        self.cfg = cfg or NetModelConfig()

    # ------------------------------------------------------------- bandwidth
    def _size_ramp(self, size_bytes: float, half: float) -> float:
        # Saturating latency-bandwidth ramp: bw(s) = peak * s / (s + half).
        return size_bytes / (size_bytes + half)

    def _spread_penalty(
        self, spread: int, max_deg: float, hops: Optional[int] = None
    ) -> float:
        """Linear degradation in the number of *extra* minipods spanned,
        saturating at the paper's measured maximum."""
        extra = max(0, spread - 1)
        frac = min(1.0, extra / max(1, self.cfg.max_spread_ref - 1))
        return 1.0 - max_deg * frac

    def collective_busbw(
        self, size_bytes: float, spread: int, hops: Optional[int] = None
    ) -> float:
        """All-reduce / all-gather / reduce-scatter BusBw (bytes/s)."""
        c = self.cfg
        return (
            c.peak_busbw
            * self._size_ramp(size_bytes, c.collective_half_size)
            * self._spread_penalty(spread, c.collective_max_degradation, hops)
        )

    def p2p_busbw(
        self, size_bytes: float, spread: int, hops: Optional[int] = None
    ) -> float:
        """send-recv BusBw (bytes/s); much more spread-sensitive (Fig. 4c)."""
        c = self.cfg
        return (
            c.peak_busbw
            * self._size_ramp(size_bytes, c.p2p_half_size)
            * self._spread_penalty(spread, c.p2p_max_degradation, hops)
        )

    def interference(self, spread: int, rng: np.random.Generator | None = None) -> float:
        """Multiplicative slowdown from co-tenant traffic (Appendix D)."""
        frac = min(1.0, max(0, spread - 1) / 4)
        jitter = self.cfg.interference_max * frac
        if rng is None:
            return 1.0 + jitter / 2
        return 1.0 + float(rng.uniform(0.0, jitter))


# ---------------------------------------------------------------------------
# Per-fabric network models (DESIGN.md §9.3).
# ---------------------------------------------------------------------------

class FabricNetModel(NetModel):
    """Degradation derived from the fabric's hop-distance structure.

    The CLOS-only ``max_spread_ref`` linear ramp is replaced by a hop
    fraction: the group's hop diameter (measured from the placement when
    the caller has one, else the fabric's tightest ``spread``-domain ball
    via :meth:`repro.topo.Fabric.distance_at_spread`) normalized by the
    fabric diameter.  Subclasses supply per-topology calibration
    constants; this generic base is used for fabrics without a bespoke
    model.
    """

    kind = "generic"

    def __init__(self, fabric: Fabric, cfg: NetModelConfig | None = None):
        super().__init__(cfg or self.default_config(fabric))
        self.fabric = fabric

    @classmethod
    def default_config(cls, fabric: Fabric) -> NetModelConfig:
        return NetModelConfig()

    def _hop_fraction(self, spread: int, hops: Optional[int] = None) -> float:
        d = hops if hops is not None else self.fabric.distance_at_spread(int(spread))
        return min(1.0, d / max(1, self.fabric.diameter()))

    def _spread_penalty(
        self, spread: int, max_deg: float, hops: Optional[int] = None
    ) -> float:
        return 1.0 - max_deg * self._hop_fraction(spread, hops)


class ClosNetModel(FabricNetModel):
    """The paper's Fig. 4 calibration on the ``clos`` fabric.

    CLOS has a uniform core, so degradation stays the legacy linear ramp
    in the number of minipods spanned -- this model is output-identical
    to :class:`NetModel` (asserted in tests/test_topo.py), keeping every
    pre-fabric benchmark number unchanged.
    """

    kind = "clos"

    def _spread_penalty(
        self, spread: int, max_deg: float, hops: Optional[int] = None
    ) -> float:
        return NetModel._spread_penalty(self, spread, max_deg)


class RailOnlyNetModel(FabricNetModel):
    """Rail-only fabric (arXiv:2307.12169): no core layer.

    Inside one rail group every rail is a single switch hop, so collectives
    run at near-CLOS efficiency; *crossing* rail groups has no switching
    layer and must forward through GPUs, so the penalty is a step
    function -- the hop fraction jumps straight to 1 for any multi-group
    placement -- and send-recv degradation is close to total.
    """

    kind = "rail-only"

    @classmethod
    def default_config(cls, fabric: Fabric) -> NetModelConfig:
        return NetModelConfig(
            collective_max_degradation=0.30,
            p2p_max_degradation=0.90,
        )


class TorusNetModel(FabricNetModel):
    """2D/3D ICI torus: graded multi-hop locality (DESIGN.md §3).

    Peak BusBw is the per-link ICI constant; the low-latency ICI links
    saturate at much smaller messages than the IB CLOS (smaller half
    sizes), and degradation grows smoothly with the placement's hop
    diameter over the torus diameter -- multi-hop rings pay per-hop
    forwarding plus contention on shared links.
    """

    kind = "torus"

    @classmethod
    def default_config(cls, fabric: Fabric) -> NetModelConfig:
        return NetModelConfig(
            peak_busbw=TPU_ICI_BW,
            collective_half_size=4 * MB,
            p2p_half_size=0.125 * MB,
            collective_max_degradation=0.45,
            p2p_max_degradation=0.60,
        )


class DragonflyNetModel(FabricNetModel):
    """Dragonfly (arXiv:2407.20018 §3.2): local meshes + global links.

    Spilling across routers of one group costs a direct local link
    (mild); spilling across groups routes over the shared global links
    whose contention under minimal routing is the dominant effect --
    moderate for bandwidth-optimal collectives, harsher for send-recv
    streams pinned to a single global path.
    """

    kind = "dragonfly"

    @classmethod
    def default_config(cls, fabric: Fabric) -> NetModelConfig:
        return NetModelConfig(
            collective_max_degradation=0.25,
            p2p_max_degradation=0.45,
        )


_NET_MODELS: dict[str, type[FabricNetModel]] = {}


def register_fabric_net_model(kind: str, cls: type[FabricNetModel] | None = None):
    """Associate a :class:`FabricNetModel` subclass with a fabric kind
    (usable as a decorator); :func:`fabric_net_model` dispatches on it."""

    def _register(obj):
        _NET_MODELS[kind] = obj
        return obj

    return _register if cls is None else _register(cls)


for _cls in (ClosNetModel, RailOnlyNetModel, TorusNetModel, DragonflyNetModel):
    register_fabric_net_model(_cls.kind, _cls)


def fabric_net_model(
    fabric: Fabric, cfg: NetModelConfig | None = None
) -> FabricNetModel:
    """The calibrated network model for ``fabric`` (its family's model, or
    the generic hop-fraction model for unregistered fabric kinds)."""
    cls = _NET_MODELS.get(fabric.kind, FabricNetModel)
    return cls(fabric, cfg)


@dataclasses.dataclass
class StepTimeBreakdown:
    """Per-step time decomposition of the simulated training step (s)."""

    compute: float
    dp_exposed: float
    pp_exposed: float
    ep_exposed: float
    total: float

    def comm_fraction(self) -> float:
        comm = self.dp_exposed + self.pp_exposed + self.ep_exposed
        return comm / self.total if self.total else 0.0


def simulate_step_time(
    comm,
    dp_spread: int,
    pp_spread: int,
    net: NetModel | None = None,
    peak_flops: float = H800_PEAK_FLOPS,
    mfu: float = 0.40,
    overlap: float = 0.65,
    rng: np.random.Generator | None = None,
    dp_hops: Optional[int] = None,
    pp_hops_diameter: Optional[int] = None,
) -> StepTimeBreakdown:
    """End-to-end step-time model for an LPJ under a given placement spread.

    compute:  6 * params_per_gpu * tokens_per_gpu / (peak * MFU)
    DP:       v_d / busbw(collective, dp_spread)  (once per step, partially
              overlapped with backward compute)
    PP:       per-microbatch boundary send-recv on the critical path:
              (pp - 1 + m - 1) activations forward + same backward, with
              v_p per boundary, at P2P BusBw(pp_spread)
    EP (MoE): all-to-all per microbatch at collective BusBw(max spread).

    ``overlap`` is the fraction of communication hideable under compute
    (Fig. 1a shows 30-50% of step time is *exposed* communication in
    production; the default calibrates to that range).

    ``dp_hops``/``pp_hops_diameter`` are the placement's measured hop
    diameters per axis (:func:`repro.core.spread.max_hop_diameters`);
    :class:`FabricNetModel` uses them for distance-accurate degradation,
    the CLOS-calibrated base model ignores them.
    """
    net = net or NetModel()
    job = comm.job
    m = job.n_microbatches
    model = job.model

    tokens_per_gpu = model.micro_batch * model.seq_len * m
    params_per_gpu = comm.v_w / model.bytes_per_element
    compute = 6.0 * params_per_gpu * tokens_per_gpu / (peak_flops * mfu)

    dp_time = comm.v_d / net.collective_busbw(
        comm.v_d, max(1, dp_spread), hops=dp_hops
    )
    pp_hops = (job.pp - 1) + (m - 1) if job.pp > 1 else 0
    pp_time = (
        2.0 * pp_hops * comm.v_p
        / net.p2p_busbw(comm.v_p, max(1, pp_spread), hops=pp_hops_diameter)
        if job.pp > 1
        else 0.0
    )
    ep_hops = None
    if dp_hops is not None or pp_hops_diameter is not None:
        ep_hops = max(dp_hops or 0, pp_hops_diameter or 0)
    ep_time = (
        m * comm.v_e / net.collective_busbw(
            comm.v_e, max(1, max(dp_spread, pp_spread)), hops=ep_hops
        )
        if comm.v_e
        else 0.0
    )

    interference = net.interference(max(dp_spread, pp_spread), rng)
    dp_exposed = dp_time * (1 - overlap) * interference
    pp_exposed = pp_time * (1 - overlap * 0.5) * interference  # P2P overlaps worse
    ep_exposed = ep_time * (1 - overlap) * interference
    total = compute + dp_exposed + pp_exposed + ep_exposed
    return StepTimeBreakdown(
        compute=compute,
        dp_exposed=dp_exposed,
        pp_exposed=pp_exposed,
        ep_exposed=ep_exposed,
        total=total,
    )
