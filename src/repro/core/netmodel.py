"""Analytical network performance model calibrated to the paper's
characterization study (§4, Fig. 4; Appendix D).

This container is CPU-only, so the NCCL-test measurements cannot be re-run;
instead we encode the paper's measured behaviour as an alpha-beta
(latency-bandwidth) model with a spread-dependent degradation term:

* BusBw ramps with message size: collectives need >= ~256 MB to saturate,
  send-recv saturates at ~2 MB (Fig. 4a).
* Spanning additional minipods degrades BusBw by up to 17% for collectives
  and up to 70% for P2P send-recv (Fig. 4b/4c).
* Multi-tenant interference adds up to ~5% jitter for jobs spanning many
  minipods (Appendix D).

The same interface carries the TPU-target constants (DESIGN.md §3) used by
the roofline analysis: 197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s
per ICI link.
"""

from __future__ import annotations

import dataclasses

import numpy as np

MB = 1 << 20
GB = 1 << 30

# ----------------------------------------------------------------- hardware
#: TPU v5e-class target constants (per chip), used by roofline analysis.
TPU_PEAK_FLOPS = 197e12      # bf16 FLOP/s
TPU_HBM_BW = 819e9           # bytes/s
TPU_ICI_BW = 50e9            # bytes/s per link

#: H800/IB cluster constants from the paper's environment (§2): 400 Gbps NIC
#: per GPU -> 50 GB/s inter-node per GPU; NVLink intra-node.
IB_PEAK_BUSBW = 50e9         # bytes/s, saturated inter-node BusBw per rank
H800_PEAK_FLOPS = 990e12     # fp16 dense


@dataclasses.dataclass(frozen=True)
class NetModelConfig:
    peak_busbw: float = IB_PEAK_BUSBW
    # Fig. 4a saturation points.
    collective_half_size: float = 48 * MB   # ~256MB to reach >90% of peak
    p2p_half_size: float = 0.25 * MB        # ~2MB saturates
    # Fig. 4b/4c: max degradation at max spread.
    collective_max_degradation: float = 0.17
    p2p_max_degradation: float = 0.70
    max_spread_ref: int = 3                 # spread where max degradation hits
    # Appendix D: co-tenancy interference ceiling.
    interference_max: float = 0.05


class NetModel:
    """BusBw and step-time estimates as a function of message size & spread."""

    def __init__(self, cfg: NetModelConfig | None = None):
        self.cfg = cfg or NetModelConfig()

    # ------------------------------------------------------------- bandwidth
    def _size_ramp(self, size_bytes: float, half: float) -> float:
        # Saturating latency-bandwidth ramp: bw(s) = peak * s / (s + half).
        return size_bytes / (size_bytes + half)

    def _spread_penalty(self, spread: int, max_deg: float) -> float:
        """Linear degradation in the number of *extra* minipods spanned,
        saturating at the paper's measured maximum."""
        extra = max(0, spread - 1)
        frac = min(1.0, extra / max(1, self.cfg.max_spread_ref - 1))
        return 1.0 - max_deg * frac

    def collective_busbw(self, size_bytes: float, spread: int) -> float:
        """All-reduce / all-gather / reduce-scatter BusBw (bytes/s)."""
        c = self.cfg
        return (
            c.peak_busbw
            * self._size_ramp(size_bytes, c.collective_half_size)
            * self._spread_penalty(spread, c.collective_max_degradation)
        )

    def p2p_busbw(self, size_bytes: float, spread: int) -> float:
        """send-recv BusBw (bytes/s); much more spread-sensitive (Fig. 4c)."""
        c = self.cfg
        return (
            c.peak_busbw
            * self._size_ramp(size_bytes, c.p2p_half_size)
            * self._spread_penalty(spread, c.p2p_max_degradation)
        )

    def interference(self, spread: int, rng: np.random.Generator | None = None) -> float:
        """Multiplicative slowdown from co-tenant traffic (Appendix D)."""
        frac = min(1.0, max(0, spread - 1) / 4)
        jitter = self.cfg.interference_max * frac
        if rng is None:
            return 1.0 + jitter / 2
        return 1.0 + float(rng.uniform(0.0, jitter))


@dataclasses.dataclass
class StepTimeBreakdown:
    """Per-step time decomposition of the simulated training step (s)."""

    compute: float
    dp_exposed: float
    pp_exposed: float
    ep_exposed: float
    total: float

    def comm_fraction(self) -> float:
        comm = self.dp_exposed + self.pp_exposed + self.ep_exposed
        return comm / self.total if self.total else 0.0


def simulate_step_time(
    comm,
    dp_spread: int,
    pp_spread: int,
    net: NetModel | None = None,
    peak_flops: float = H800_PEAK_FLOPS,
    mfu: float = 0.40,
    overlap: float = 0.65,
    rng: np.random.Generator | None = None,
) -> StepTimeBreakdown:
    """End-to-end step-time model for an LPJ under a given placement spread.

    compute:  6 * params_per_gpu * tokens_per_gpu / (peak * MFU)
    DP:       v_d / busbw(collective, dp_spread)  (once per step, partially
              overlapped with backward compute)
    PP:       per-microbatch boundary send-recv on the critical path:
              (pp - 1 + m - 1) activations forward + same backward, with
              v_p per boundary, at P2P BusBw(pp_spread)
    EP (MoE): all-to-all per microbatch at collective BusBw(max spread).

    ``overlap`` is the fraction of communication hideable under compute
    (Fig. 1a shows 30-50% of step time is *exposed* communication in
    production; the default calibrates to that range).
    """
    net = net or NetModel()
    job = comm.job
    m = job.n_microbatches
    model = job.model

    tokens_per_gpu = model.micro_batch * model.seq_len * m
    params_per_gpu = comm.v_w / model.bytes_per_element
    compute = 6.0 * params_per_gpu * tokens_per_gpu / (peak_flops * mfu)

    dp_time = comm.v_d / net.collective_busbw(comm.v_d, max(1, dp_spread))
    pp_hops = (job.pp - 1) + (m - 1) if job.pp > 1 else 0
    pp_time = (
        2.0 * pp_hops * comm.v_p / net.p2p_busbw(comm.v_p, max(1, pp_spread))
        if job.pp > 1
        else 0.0
    )
    ep_time = (
        m * comm.v_e / net.collective_busbw(comm.v_e, max(1, max(dp_spread, pp_spread)))
        if comm.v_e
        else 0.0
    )

    interference = net.interference(max(dp_spread, pp_spread), rng)
    dp_exposed = dp_time * (1 - overlap) * interference
    pp_exposed = pp_time * (1 - overlap * 0.5) * interference  # P2P overlaps worse
    ep_exposed = ep_time * (1 - overlap) * interference
    total = compute + dp_exposed + pp_exposed + ep_exposed
    return StepTimeBreakdown(
        compute=compute,
        dp_exposed=dp_exposed,
        pp_exposed=pp_exposed,
        ep_exposed=ep_exposed,
        total=total,
    )
