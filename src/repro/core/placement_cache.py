"""Placement cache for recurring job shapes (DESIGN.md §8.3).

Continuous job churn on a 10k-node cluster re-solves near-identical
placement problems all day: the same model/parallelism template arrives
many times, and between arrivals the free pool drifts by only a few nodes.
The cache memoizes the solved **counts matrix** (nodes per scheduling-unit
group per minipod) -- deliberately *not* node ids, which change as jobs
come and go -- keyed on everything that determines the solve:

    (matrix shape, scheduling unit, affinity weights,
     quantized free-capacity signature)

Free capacities enter the key through :meth:`Cluster.free_signature`, which
rounds each minipod's free count down to a multiple of ``quantum`` nodes.
Quantization is what makes the cache useful: without it, a single node
allocated anywhere in the cluster would change the key and nothing would
ever hit.  A hit is revalidated against the *exact* current free
capacities before any placement is materialized, so a stale entry can
never produce an infeasible placement -- it just counts as a miss.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Hashable, Optional

import numpy as np

from repro.core.comm_matrix import CommMatrix
from repro.core.topology import Cluster

CacheKey = tuple


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0

    @property
    def lookups(self) -> int:
        return self.hits + self.misses

    def hit_rate(self) -> float:
        return self.hits / self.lookups if self.lookups else 0.0

    def as_dict(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "hit_rate": round(self.hit_rate(), 4)}


class PlacementCache:
    """LRU cache of solved counts matrices, validated on every hit.

    ``quantum`` is the free-capacity quantization step (nodes); ``maxsize``
    bounds memory (oldest entry evicted first).  Thread-unsafe by design:
    schedulers run in the single-threaded scheduling loop.
    """

    def __init__(self, quantum: int = 8, maxsize: int = 256):
        if quantum < 1:
            raise ValueError(f"quantum must be >= 1, got {quantum}")
        self.quantum = quantum
        self.maxsize = maxsize
        self._entries: OrderedDict[CacheKey, np.ndarray] = OrderedDict()
        self.stats = CacheStats()

    def __len__(self) -> int:
        return len(self._entries)

    # ------------------------------------------------------------------ key
    def key(
        self,
        comm: CommMatrix,
        cluster: Cluster,
        unit: str,
        alpha: float,
        beta: float,
        extra: Hashable = (),
    ) -> CacheKey:
        """Cache key for one placement problem.

        ``extra`` lets a scheduler fold in algorithm knobs that change the
        solution (e.g. the hierarchical block size).
        """
        return (
            comm.shape,
            unit,
            round(float(alpha), 6),
            round(float(beta), 6),
            cluster.n_minipods,
            cluster.free_signature(self.quantum),
            extra,
        )

    # --------------------------------------------------------------- lookup
    def lookup(self, key: CacheKey, free: np.ndarray) -> Optional[np.ndarray]:
        """Return validated counts for ``key``, or None (counts a miss).

        Validation: the cached per-minipod demands must fit the *exact*
        current free capacities (quantized signatures can match while a pod
        lost a node the cached solution needs).
        """
        entry = self._entries.get(key)
        if entry is not None and (entry.sum(axis=0) <= np.asarray(free)).all():
            self._entries.move_to_end(key)
            self.stats.hits += 1
            return entry.copy()
        self.stats.misses += 1
        return None

    def store(self, key: CacheKey, counts: np.ndarray) -> None:
        self._entries[key] = np.asarray(counts, dtype=int).copy()
        self._entries.move_to_end(key)
        while len(self._entries) > self.maxsize:
            self._entries.popitem(last=False)

    def clear(self) -> None:
        self._entries.clear()
        self.stats = CacheStats()
