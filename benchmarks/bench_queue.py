"""Figure 14 / Appendix H reproduction: allocation + retention rate over the
reservation window, with/without strong reservation and JCT-guided backfill.

Paper shape: Arnold is told the LPJ arrival 4 h ahead; retention decays to
~0 by arrival (reserved nodes drained), while best-effort reservation leaves
squatters that need manual preemption; disabling JCT backfill idles the
reserved zone (lower allocation).
"""

import time

import numpy as np

from repro.core import (
    Cluster,
    JCTPredictor,
    JobSpec,
    ModelSpec,
    QueuePolicy,
    TraceSimulator,
    build_comm_matrix,
    poisson_trace,
    synthetic_trace,
)

MODEL7B = ModelSpec(
    name="gpt-7b", hidden=4096, layers=32, vocab=50304, seq_len=2048,
    global_batch=1024, micro_batch=1, d_ff=16384,
)


def _sim(reserve: bool, use_jct: bool, seed: int = 0):
    cluster = Cluster.uniform(8, 20)  # 160 nodes
    jobs, jct = synthetic_trace(600, seed=seed)
    pred = JCTPredictor(n_bags=2, n_rounds=25).fit(jobs, jct)
    policy = QueuePolicy(cluster, jct_predictor=pred, reserve=reserve,
                         use_jct=use_jct)
    sim = TraceSimulator(policy, tick=120.0)
    trace = poisson_trace(250, mean_interarrival=60.0, mean_duration=2400.0,
                          max_nodes=24, seed=seed)
    comm = build_comm_matrix(
        JobSpec(n_gpus=96 * 8, tp=8, pp=4, model=MODEL7B)  # 96-node LPJ
    )
    res = sim.run(trace, t_end=6 * 3600.0,
                  lpj_plan=(comm, 4 * 3600.0, 0.3, "pp"),
                  plan_at=1800.0)
    post_plan = [p for p in res.series if 1800.0 < p.t <= 4 * 3600.0]
    final_ret = np.mean([p.retention_rate for p in post_plan[-5:]])
    mean_alloc = np.mean([p.allocation_rate for p in post_plan])
    return float(final_ret), float(mean_alloc), res.manual_preemptions


def run() -> list[tuple]:
    rows = []
    t0 = time.perf_counter()
    ret_a, alloc_a, pre_a = _sim(reserve=True, use_jct=True)
    dt = (time.perf_counter() - t0) * 1e6
    rows.append(("queue_arnold_final_retention", dt, round(ret_a, 3)))
    rows.append(("queue_arnold_mean_allocation", 0.0, round(alloc_a, 3)))
    rows.append(("queue_arnold_preempted_at_lpj", 0.0, pre_a))

    ret_b, alloc_b, pre_b = _sim(reserve=False, use_jct=True)
    rows.append(("queue_noreserve_final_retention", 0.0, round(ret_b, 3)))
    rows.append(("queue_noreserve_preempted_at_lpj", 0.0, pre_b))

    ret_c, alloc_c, _ = _sim(reserve=True, use_jct=False)
    rows.append(("queue_nojct_mean_allocation", 0.0, round(alloc_c, 3)))

    # paper-shape checks (Fig. 14): reservation drains the planned zone;
    # JCT backfill raises utilization of the reserved zone
    rows.append(("paper_claim_retention_drains_ok", 0.0,
                 int(ret_a < ret_b)))
    rows.append(("paper_claim_jct_raises_allocation_ok", 0.0,
                 int(alloc_a >= alloc_c - 1e-9)))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
