"""Cross-fabric scheduling comparison (DESIGN.md §9): Arnold's MILP vs the
best classical baseline on capacity-matched clos / rail-only / torus /
dragonfly fabrics, scored by Eq. 2 weighted spread and by simulated step
time under each fabric's calibrated network model.

Emits ``BENCH_topology.json`` (schema 1, :mod:`benchmarks._artifact`) with
one Arnold-vs-best-baseline metric pair per fabric, so cross-PR tooling can
track whether topology-aware placement keeps its edge off the paper's CLOS.
"""

import time

import numpy as np

from repro.core import (
    Cluster,
    JobSpec,
    ModelSpec,
    ScheduleRequest,
    build_comm_matrix,
    get_scheduler,
    list_schedulers,
    throughput_of_placement,
    weighted_spread,
)
from repro.topo import comparable_fabric

from benchmarks._artifact import artifact_path, write_bench

BENCH_FILE = artifact_path("topology")

#: fabrics under comparison; rows appear in the artifact in this order.
FABRICS = ("clos", "rail-only", "torus", "dragonfly")

MODEL = ModelSpec(
    name="dense-24b", hidden=6144, layers=52, vocab=100352, seq_len=4096,
    global_batch=1024, micro_batch=1, d_ff=24576,
)

#: Arnold-family tiers are not baselines (same policy family).
_NON_BASELINES = ("mip", "hier")


def _fragment(cluster: Cluster, n_cells: int, frac: float, seed: int) -> None:
    """Occupy ``frac`` of the cluster at random, leaving room for the job."""
    rng = np.random.default_rng(seed)
    max_busy = cluster.n_nodes - n_cells
    busy = rng.choice(
        cluster.n_nodes,
        size=min(int(frac * cluster.n_nodes), max_busy),
        replace=False,
    )
    cluster.allocate([int(b) for b in busy])


def _one_fabric(kind: str, caps: list, tp: int, pp: int, n_nodes: int,
                alpha: float, frac: float, seed: int) -> dict:
    """Arnold vs best baseline on one fabric: spread and simulated step time."""
    comm = build_comm_matrix(JobSpec(n_gpus=n_nodes * 8, tp=tp, pp=pp, model=MODEL))

    cluster = Cluster.from_fabric(comparable_fabric(kind, caps))
    _fragment(cluster, comm.n_cells, frac, seed)
    request = ScheduleRequest(comm=comm, cluster=cluster, alpha=alpha, seed=seed)
    ours = get_scheduler("mip").schedule(request).placement
    t_ours = throughput_of_placement(ours, steps=3)

    best_name, best_spread, best_tp = None, float("inf"), None
    for name in list_schedulers():
        if name in _NON_BASELINES:
            continue
        try:
            placement = get_scheduler(name).schedule(request).placement
        except Exception:  # noqa: BLE001 -- infeasible baselines just lose
            continue
        s = weighted_spread(placement, alpha)
        if s < best_spread:
            best_name, best_spread = name, s
            best_tp = throughput_of_placement(placement, steps=3)

    ours_spread = weighted_spread(ours, alpha)
    return {
        "arnold_spread": float(ours_spread),
        "baseline_spread": float(best_spread),
        "arnold_step_s": float(t_ours["step_time_s"]),
        "baseline_step_s": float(best_tp["step_time_s"]),
        "arnold_tokens_per_s": float(t_ours["tokens_per_s"]),
        "baseline_tokens_per_s": float(best_tp["tokens_per_s"]),
        "gain_pct": 100.0 * (t_ours["tokens_per_s"] / best_tp["tokens_per_s"] - 1.0),
        "best_baseline": best_name,
    }


def run(smoke: bool = False) -> list[tuple]:
    # 16 domains of 24 nodes; the job takes 64 nodes (512 GPUs) on the full
    # run, 16 nodes on --smoke (same code path, CI-sized solve).
    caps = [24] * 16
    n_nodes, tp, pp = (16, 8, 2) if smoke else (64, 8, 4)
    # smoke shrinks the job, so fragmentation is raised to keep the
    # placement contended (otherwise every algorithm consolidates to 0)
    alpha, frac, seed = 0.3, (0.8 if smoke else 0.35), 7

    rows: list[tuple] = []
    metrics: dict[str, float] = {}
    best_names: dict[str, str] = {}
    for kind in FABRICS:
        t0 = time.perf_counter()
        r = _one_fabric(kind, caps, tp, pp, n_nodes, alpha, frac, seed)
        dt = (time.perf_counter() - t0) * 1e6
        key = kind.replace("-", "_")
        for m in ("arnold_spread", "baseline_spread",
                  "arnold_step_s", "baseline_step_s", "gain_pct"):
            metrics[f"{key}_{m}"] = round(r[m], 6)
        best_names[key] = r["best_baseline"]
        rows.append((f"topology_{key}_arnold_spread", dt, round(r["arnold_spread"], 3)))
        rows.append((f"topology_{key}_baseline_spread", 0.0, round(r["baseline_spread"], 3)))
        rows.append((f"topology_{key}_gain_pct", 0.0, round(r["gain_pct"], 2)))

    write_bench(
        "topology",
        workload={
            "model": MODEL.name,
            "n_domains": len(caps),
            "nodes_per_domain": caps[0],
            "job_nodes": n_nodes,
            "tp": tp,
            "pp": pp,
            "alpha": alpha,
            "fragment_frac": frac,
            "seed": seed,
            "smoke": smoke,
            "fabrics": ",".join(FABRICS),
        },
        metrics=metrics,
        best_baselines=best_names,
    )
    rows.append(("topology_artifact", 0.0, BENCH_FILE.name))
    return rows


if __name__ == "__main__":
    import sys
    for row in run(smoke="--smoke" in sys.argv[1:]):
        print(",".join(str(x) for x in row))
