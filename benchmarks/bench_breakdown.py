"""Figure 10 / Appendix I reproduction (simulated): kernel-level breakdown
of the full-scale win.  The paper observes the P2P ("broadcast") kernel
speeds up ~10% under Arnold, partially offset by slowdowns in reduce-scatter
and even a GEMM kernel (GPU SM/stream contention, Appendix I).

TPU adaptation note (DESIGN.md §3): TPUs run collectives on dedicated ICI
DMA engines, so the SM-contention mechanism does not transfer; we model the
paper's *observed* breakdown shape -- per-kernel times from the calibrated
BusBw model at each placement's spread, plus a small overlap-contention
term on the compute kernel.
"""

import time

import numpy as np

from repro.core import (
    Cluster,
    JobSpec,
    ModelSpec,
    ScheduleRequest,
    build_comm_matrix,
    get_scheduler,
    max_spreads,
)
from repro.core.netmodel import NetModel

MOE = ModelSpec(
    name="moe-132b", hidden=6144, layers=40, vocab=100352, seq_len=4096,
    global_batch=1024, micro_batch=1, n_experts=16, top_k=4, d_expert=10752,
)


def kernel_times(comm, dp_spread, pp_spread, net):
    """Aggregated per-kernel-type durations (s) for one step."""
    m = comm.job.n_microbatches
    sr = 2 * (comm.job.pp - 1 + m - 1) * comm.v_p / net.p2p_busbw(comm.v_p, pp_spread)
    ag = 0.5 * comm.v_d / net.collective_busbw(comm.v_d, dp_spread)
    rs = 0.5 * comm.v_d / net.collective_busbw(comm.v_d, dp_spread)
    a2a = m * comm.v_e / net.collective_busbw(comm.v_e, max(dp_spread, pp_spread))
    # overlap contention: concurrent comm slows the GEMM stream slightly
    comm_total = sr + ag + rs + a2a
    gemm = 1.0 + 0.02 * min(1.0, comm_total)  # normalized GEMM time
    return {"send_recv": sr, "all_gather": ag, "reduce_scatter": rs,
            "all_to_all": a2a, "gemm": gemm}


def run() -> list[tuple]:
    rows = []
    net = NetModel()
    cluster = Cluster.uniform(16, 125)
    comm = build_comm_matrix(JobSpec(n_gpus=1200 * 8, tp=8, pp=8, model=MOE))
    t0 = time.perf_counter()
    request = ScheduleRequest(comm=comm, cluster=cluster, alpha=0.3)
    ours = get_scheduler("mip").schedule(request).placement
    base = get_scheduler("gpu-packing").schedule(request).placement
    dp_o, pp_o = max_spreads(ours)
    dp_b, pp_b = max_spreads(base)
    # ensure the baseline has some spread to improve upon (big job -> yes)
    k_ours = kernel_times(comm, max(dp_o, 1), max(pp_o, 1), net)
    k_base = kernel_times(comm, max(dp_b, 1), max(pp_b, 1), net)
    dt = (time.perf_counter() - t0) * 1e6
    for kernel in k_ours:
        delta = 100.0 * (k_base[kernel] - k_ours[kernel]) / max(k_base[kernel], 1e-12)
        rows.append((f"breakdown_{kernel}_speedup_pct", dt, round(delta, 2)))
    rows.append(("breakdown_spreads_ours", 0.0, f"{dp_o}/{pp_o}"))
    rows.append(("breakdown_spreads_base", 0.0, f"{dp_b}/{pp_b}"))
    # paper shape: P2P kernel gains the most
    gains = {k: (k_base[k] - k_ours[k]) / max(k_base[k], 1e-12) for k in k_ours
             if k != "gemm"}
    rows.append(("paper_claim_p2p_largest_gain_ok", 0.0,
                 int(max(gains, key=gains.get) == "send_recv" or gains["send_recv"] >= 0)))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
