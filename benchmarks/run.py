"""Benchmark harness: one module per paper table/figure (DESIGN.md §5).

Prints ``name,us_per_call,derived`` CSV rows.  Run as:
    PYTHONPATH=src python -m benchmarks.run            # all
    PYTHONPATH=src python -m benchmarks.run bench_e2e  # one
    PYTHONPATH=src python -m benchmarks.run latency serve --smoke  # CI
    PYTHONPATH=src python -m benchmarks.run --list    # areas + artifacts

Modules whose ``run`` accepts a ``smoke`` argument honor ``--smoke``
(shrunk workload, same code paths).  Modules with a ``BENCH_FILE``
attribute emit a cross-PR ``BENCH_<area>.json`` artifact (schema in
:mod:`benchmarks._artifact`).
"""

import inspect
import pathlib
import sys
import time
import traceback

if __package__ in (None, ""):  # `python benchmarks/run.py ...` (script mode)
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))

from benchmarks import (
    bench_breakdown,
    bench_comm,
    bench_e2e,
    bench_jct,
    bench_latency,
    bench_queue,
    bench_serve,
    bench_spread,
    bench_topology,
    bench_volume,
    roofline_report,
)

ALL = {
    "bench_volume": bench_volume,      # Appendix C (2 GB / 30 MB claim)
    "bench_comm": bench_comm,          # Figure 4 (BusBw model)
    "bench_spread": bench_spread,      # Figure 7 / Table 1
    "bench_latency": bench_latency,    # Figure 8 + scale tier -> BENCH_sched_latency.json
    "bench_e2e": bench_e2e,            # Figures 5 + 9 (simulated E2E)
    "bench_topology": bench_topology,  # DESIGN.md §9 cross-fabric -> BENCH_topology.json
    "bench_queue": bench_queue,        # Figure 14 / Appendix H
    "bench_jct": bench_jct,            # Figure 13 / Appendix G
    "bench_breakdown": bench_breakdown,  # Figure 10 / Appendix I
    "bench_serve": bench_serve,        # DESIGN.md §7 -> BENCH_serve.json
    "roofline_report": roofline_report,  # §Roofline table from the dry-run
}

ALIASES = {"serve": "bench_serve", "latency": "bench_latency",
           "topology": "bench_topology"}


def artifact_of(mod) -> "pathlib.Path | None":
    """The BENCH_*.json this module emits, if any."""
    return getattr(mod, "BENCH_FILE", None)


def list_areas() -> None:
    for name, mod in ALL.items():
        art = artifact_of(mod)
        smoke = "smoke" in inspect.signature(mod.run).parameters
        tags = [t for t, on in (("--smoke", smoke),) if on]
        if art is not None:
            tags.append(f"emits {art.name}")
        print(f"{name:<18} {' '.join(tags)}".rstrip())


def main() -> None:
    argv = sys.argv[1:]
    smoke = "--smoke" in argv
    argv = [a for a in argv if a != "--smoke"]
    if "--list" in argv:
        list_areas()
        return
    names = [ALIASES.get(n, n) for n in argv] or list(ALL)
    unknown = [n for n in names if n not in ALL]
    if unknown:
        print(f"unknown benchmark(s) {unknown}; available: {list(ALL)}",
              file=sys.stderr)
        sys.exit(2)
    print("name,us_per_call,derived")
    failures = 0
    for name in names:
        mod = ALL[name]
        kwargs = {}
        if smoke and "smoke" in inspect.signature(mod.run).parameters:
            kwargs["smoke"] = True
        t0 = time.perf_counter()
        try:
            rows = mod.run(**kwargs)
        except Exception as e:  # noqa: BLE001
            failures += 1
            traceback.print_exc(file=sys.stderr)
            print(f"{name}_FAILED,0,{type(e).__name__}")
            continue
        wall = (time.perf_counter() - t0) * 1e6
        for r in rows:
            print(",".join(str(x) for x in r))
        print(f"{name}_total,{wall:.0f},ok")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
