"""Figure 4 reproduction (modeled): BusBw vs message size and minipod spread.

Encodes the paper's measured curves: collectives need ~256 MB to saturate,
send-recv saturates at ~2 MB; spanning extra minipods costs up to 17%
(collectives) / 70% (P2P).
"""

import time

from repro.core.netmodel import MB, NetModel


def run() -> list[tuple]:
    net = NetModel()
    rows = []
    t0 = time.perf_counter()
    for size_mb in (1, 8, 64, 256, 2048):
        bw = net.collective_busbw(size_mb * MB, spread=1) / 1e9
        rows.append((f"busbw_collective_{size_mb}MB_spread1_GBps",
                     (time.perf_counter() - t0) * 1e6, round(bw, 2)))
    for size_mb in (0.25, 2, 32):
        bw = net.p2p_busbw(size_mb * MB, spread=1) / 1e9
        rows.append((f"busbw_p2p_{size_mb}MB_spread1_GBps",
                     (time.perf_counter() - t0) * 1e6, round(bw, 2)))
    # spread degradation at saturated sizes (Fig. 4b/4c)
    c1 = net.collective_busbw(2048 * MB, 1)
    c3 = net.collective_busbw(2048 * MB, 3)
    p1 = net.p2p_busbw(32 * MB, 1)
    p3 = net.p2p_busbw(32 * MB, 3)
    rows.append(("busbw_collective_degradation_spread3_pct", 0.0,
                 round(100 * (1 - c3 / c1), 1)))
    rows.append(("busbw_p2p_degradation_spread3_pct", 0.0,
                 round(100 * (1 - p3 / p1), 1)))
    rows.append(("paper_claim_17pct_collective_ok", 0.0,
                 int(abs((1 - c3 / c1) - 0.17) < 0.02)))
    rows.append(("paper_claim_70pct_p2p_ok", 0.0,
                 int(abs((1 - p3 / p1) - 0.70) < 0.02)))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
