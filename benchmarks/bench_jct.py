"""Figure 13 / Appendix G reproduction: GBM JCT predictor on a 90/10 split
of a 4-month-scale synthetic trace; paper reports RMSE 1.61 (10-min buckets)
and GBM > DNN; we compare GBM vs mean- and linear-regression baselines."""

import time

import numpy as np

from repro.core import JCTPredictor, synthetic_trace


def run() -> list[tuple]:
    rows = []
    jobs, jct = synthetic_trace(2000, seed=11)
    n_train = int(0.9 * len(jobs))
    t0 = time.perf_counter()
    pred = JCTPredictor(n_bags=5, n_rounds=60).fit(jobs[:n_train], jct[:n_train])
    fit_us = (time.perf_counter() - t0) * 1e6
    X_test = jobs[n_train:]
    true_b = JCTPredictor.to_bucket(jct[n_train:])
    gbm_b = pred.predict_bucket(X_test)
    rmse_gbm = float(np.sqrt(np.mean((gbm_b - true_b) ** 2)))

    # baselines
    train_b = JCTPredictor.to_bucket(jct[:n_train])
    rmse_mean = float(np.sqrt(np.mean((train_b.mean() - true_b) ** 2)))
    Xtr = JCTPredictor.featurize(jobs[:n_train])
    Xte = JCTPredictor.featurize(X_test)
    w, *_ = np.linalg.lstsq(
        np.c_[Xtr, np.ones(len(Xtr))], train_b, rcond=None
    )
    lin_b = np.c_[Xte, np.ones(len(Xte))] @ w
    rmse_lin = float(np.sqrt(np.mean((lin_b - true_b) ** 2)))

    rows.append(("jct_gbm_rmse_buckets", fit_us, round(rmse_gbm, 2)))
    rows.append(("jct_mean_rmse_buckets", 0.0, round(rmse_mean, 2)))
    rows.append(("jct_linear_rmse_buckets", 0.0, round(rmse_lin, 2)))
    rows.append(("jct_uncertainty_mean", 0.0,
                 round(float(np.mean(pred.uncertainty(X_test))), 3)))
    rows.append(("paper_claim_gbm_best_ok", 0.0,
                 int(rmse_gbm < min(rmse_mean, rmse_lin))))
    rows.append(("paper_rmse_1.61_band_ok", 0.0, int(rmse_gbm < 3.5)))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
