"""Figure 9 / Figure 5 reproduction (simulated): end-to-end training
throughput under Arnold vs a MegaScale-style bin-packing baseline.

Paper claims: +5.7% at 208 GPUs (26 nodes), +10.6% at 9600+ GPUs (1200+
nodes, >50% of the cluster); dense models are PP-bound (DP-aligned gives no
speedup), MoE gains from both groups; improvement grows with model scale
(Fig. 5b).  Throughput comes from the calibrated BusBw/step-time model --
the same methodology the paper uses for its own simulator experiments.

``--fabric {clos,rail-only,torus,dragonfly,all}`` re-runs the comparison on
a capacity-matched fabric of that family with its own calibrated network
model (DESIGN.md §9.3); the default (no flag) is the CLOS path,
bit-identical to the pre-fabric numbers.
"""

import sys
import time

import numpy as np

from repro.core import (
    Cluster,
    JobSpec,
    ModelSpec,
    ScheduleRequest,
    build_comm_matrix,
    get_scheduler,
    throughput_of_placement,
)
from repro.topo import comparable_fabric, list_fabrics

DENSE_24B = ModelSpec(
    name="dense-24b", hidden=6144, layers=52, vocab=100352, seq_len=4096,
    global_batch=1024, micro_batch=1, d_ff=24576,
)
MOE = ModelSpec(
    name="moe-132b", hidden=6144, layers=40, vocab=100352, seq_len=4096,
    global_batch=1024, micro_batch=1, n_experts=16, top_k=4, d_expert=10752,
)


def _cluster(n_pods: int, cap: int, fabric: "str | None") -> Cluster:
    """Uniform cluster, optionally rebuilt on another fabric family with
    the same per-domain capacities (``None`` = legacy CLOS path)."""
    if fabric is None:
        return Cluster.uniform(n_pods, cap)
    return Cluster.from_fabric(comparable_fabric(fabric, [cap] * n_pods))


def _compare(model, cluster, n_nodes, tp, pp, alpha, fragment_seed=None,
             fragment_frac=0.45):
    job = JobSpec(n_gpus=n_nodes * 8, tp=tp, pp=pp, model=model)
    comm = build_comm_matrix(job)
    if fragment_seed is not None:
        # skewed fragmentation: earlier pods more occupied (realistic shared
        # cluster), so naive consolidation crosses more pod boundaries
        rng = np.random.default_rng(fragment_seed)
        max_busy = cluster.n_nodes - comm.n_cells
        weights = np.array(
            [2.0 - cluster.nodes[n].minipod / cluster.n_minipods
             for n in range(cluster.n_nodes)]
        )
        weights = weights / weights.sum()
        busy = rng.choice(cluster.n_nodes,
                          size=min(int(fragment_frac * cluster.n_nodes), max_busy),
                          replace=False, p=weights)
        cluster.allocate([int(b) for b in busy])
    request = ScheduleRequest(comm=comm, cluster=cluster, alpha=alpha)
    ours = get_scheduler("mip").schedule(request).placement
    # MegaScale-style consolidation
    base = get_scheduler("gpu-packing").schedule(request).placement
    t_ours = throughput_of_placement(ours, steps=5)
    t_base = throughput_of_placement(base, steps=5)
    gain = 100.0 * (t_ours["tokens_per_s"] / t_base["tokens_per_s"] - 1.0)
    return gain, t_ours, t_base


def run(fabric: "str | None" = None) -> list[tuple]:
    tag = "" if fabric is None else f"{fabric}_"
    rows = []
    t0 = time.perf_counter()

    # medium scale: 26 nodes (208 GPUs, the paper's medium experiment),
    # fragmented mid-size cluster
    gain_med, to, tb = _compare(
        DENSE_24B, _cluster(8, 24, fabric), n_nodes=26, tp=8, pp=2,
        alpha=0.0, fragment_seed=1,
    )
    rows.append((f"e2e_{tag}medium_dense_gain_pct", (time.perf_counter() - t0) * 1e6,
                 round(gain_med, 2)))
    rows.append((f"e2e_{tag}medium_spreads_ours_dp_pp", 0.0,
                 f"{to['dp_spread']}/{to['pp_spread']}"))
    rows.append((f"e2e_{tag}medium_spreads_base_dp_pp", 0.0,
                 f"{tb['dp_spread']}/{tb['pp_spread']}"))

    # full scale: 1200 nodes (9600 GPUs) in a 2000-node cluster (>50% usage)
    gain_full, to, tb = _compare(
        MOE, _cluster(16, 125, fabric), n_nodes=1200, tp=8, pp=8,
        alpha=0.3, fragment_seed=2, fragment_frac=0.3,
    )
    rows.append((f"e2e_{tag}full_9600gpu_moe_gain_pct", 0.0, round(gain_full, 2)))
    rows.append((f"e2e_{tag}full_comm_fraction", 0.0, round(to["comm_fraction"], 3)))

    # Fig. 5b: improvement grows with model size.  Bigger models require
    # deeper pipelines (layers and PP scale together at fixed layers/stage),
    # which multiplies PP boundary traffic -- the paper's amplification
    # mechanism.
    gains = []
    for layers, pp, nodes in ((26, 2, 16), (52, 4, 32), (104, 8, 64)):
        model = ModelSpec(
            name=f"dense-{layers}L", hidden=6144, layers=layers, vocab=100352,
            seq_len=4096, global_batch=1024, micro_batch=1, d_ff=24576,
        )
        g, _, _ = _compare(model, _cluster(8, 24, fabric), nodes, 8, pp, 0.0,
                           fragment_seed=3)
        gains.append(g)
        rows.append((f"e2e_{tag}scaling_{layers}L_pp{pp}_gain_pct", 0.0, round(g, 2)))
    if fabric is None:
        rows.append(("paper_claim_gain_grows_with_size_ok", 0.0,
                     int(gains[0] <= gains[1] + 0.3 and gains[1] <= gains[2] + 0.3)))
        rows.append(("paper_claim_full_scale_gain_positive_ok", 0.0,
                     int(gain_full > 0)))
    return rows


if __name__ == "__main__":
    args = sys.argv[1:]
    fabrics: "list[str | None]" = [None]
    if "--fabric" in args:
        which = args[args.index("--fabric") + 1]
        fabrics = list(list_fabrics()) if which == "all" else [which]
    for f in fabrics:
        for r in run(fabric=f):
            print(",".join(str(x) for x in r))
