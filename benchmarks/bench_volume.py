"""Appendix C reproduction: analytical communication volumes.

Paper claim (§4): for a 7B GPT-based model, DP-group volume ~ 2 GB and
PP-group volume ~ 30 MB.
"""

import time

from repro.core import JobSpec, ModelSpec, build_comm_matrix

GB, MB = 1 << 30, 1 << 20


def run() -> list[tuple]:
    model7b = ModelSpec(
        name="gpt-7b", hidden=4096, layers=32, vocab=50304, seq_len=2048,
        global_batch=1024, micro_batch=1, d_ff=16384,
    )
    rows = []
    t0 = time.perf_counter()
    for pp in (2, 4, 8):
        job = JobSpec(n_gpus=64 * pp // 8 * 8, tp=4, pp=pp, model=model7b)
        comm = build_comm_matrix(job)
        rows.append((f"volume_dp_7b_pp{pp}_gb", (time.perf_counter() - t0) * 1e6,
                     round(comm.v_d / GB, 3)))
        rows.append((f"volume_pp_7b_pp{pp}_mb", (time.perf_counter() - t0) * 1e6,
                     round(comm.v_p / MB, 2)))
    # paper sanity cell: pp=8 -> ~2 GB / ~30 MB
    job = JobSpec(n_gpus=64, tp=4, pp=8, model=model7b)
    comm = build_comm_matrix(job)
    ok_dp = 1.5 < comm.v_d / GB < 2.5
    ok_pp = 25 < comm.v_p / MB < 40
    rows.append(("volume_paper_claim_dp2GB_pp30MB_ok", 0.0, int(ok_dp and ok_pp)))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
