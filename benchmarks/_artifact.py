"""Shared benchmark-artifact API (DESIGN.md §5).

Every cross-PR perf baseline lives in a ``BENCH_<area>.json`` file at the
repo root with one schema (version 1):

    {
      "schema": 1,
      "benchmark": "<area>",          # e.g. "serve", "sched_latency"
      "workload": {...},              # scalar fingerprint of what was run
      "metrics": {...},               # name -> number, the measured values
      ...extra sections...,           # free-form dicts (engine config, ...)
      "unix_time": <float>
    }

Producers call :func:`write_bench` (which validates before writing);
consumers and CI call :func:`validate_artifact` /
``python -m benchmarks._artifact FILE...`` so a malformed artifact fails
the build instead of silently breaking cross-PR comparison.
"""

from __future__ import annotations

import json
import pathlib
import sys
import time

SCHEMA_VERSION = 1
REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent

_SCALAR = (str, int, float, bool, type(None))


class ArtifactError(ValueError):
    """A payload that does not conform to the BENCH_*.json schema."""


def artifact_path(area: str) -> pathlib.Path:
    return REPO_ROOT / f"BENCH_{area}.json"


def _require(cond: bool, msg: str) -> None:
    if not cond:
        raise ArtifactError(msg)


def validate_artifact(payload: dict) -> dict:
    """Validate one artifact payload against schema 1; return it unchanged.

    ``workload`` values must be scalars (the fingerprint must stay
    diffable); ``metrics`` values must be real numbers (they are what
    cross-PR tooling plots); extra top-level sections must be dicts of
    scalars or scalars.
    """
    _require(isinstance(payload, dict), "artifact must be a JSON object")
    _require(payload.get("schema") == SCHEMA_VERSION,
             f"schema must be {SCHEMA_VERSION}, got {payload.get('schema')!r}")
    area = payload.get("benchmark")
    _require(isinstance(area, str) and bool(area),
             "benchmark must be a non-empty string")
    for section in ("workload", "metrics"):
        _require(isinstance(payload.get(section), dict),
                 f"{section} must be a dict")
    for k, v in payload["workload"].items():
        _require(isinstance(k, str) and isinstance(v, _SCALAR),
                 f"workload[{k!r}] must be a scalar, got {type(v).__name__}")
    _require(bool(payload["metrics"]), "metrics must be non-empty")
    for k, v in payload["metrics"].items():
        _require(isinstance(k, str)
                 and isinstance(v, (int, float)) and not isinstance(v, bool),
                 f"metrics[{k!r}] must be a number, got {v!r}")
    _require(isinstance(payload.get("unix_time"), (int, float)),
             "unix_time must be a number")
    for k, v in payload.items():
        if k in ("schema", "benchmark", "workload", "metrics", "unix_time"):
            continue
        _require(isinstance(v, _SCALAR) or isinstance(v, dict),
                 f"extra section {k!r} must be a scalar or dict")
        if isinstance(v, dict):
            for kk, vv in v.items():
                _require(isinstance(kk, str) and isinstance(vv, _SCALAR),
                         f"{k}[{kk!r}] must be a scalar")
    return payload


def write_bench(
    area: str,
    workload: dict,
    metrics: dict,
    *,
    path: "pathlib.Path | str | None" = None,
    **extra: dict,
) -> pathlib.Path:
    """Validate and write ``BENCH_<area>.json``; return the path written.

    ``extra`` keyword sections (e.g. ``engine={...}``) are stored at the
    top level next to ``workload``/``metrics``.
    """
    payload = {
        "schema": SCHEMA_VERSION,
        "benchmark": area,
        "workload": dict(workload),
        "metrics": dict(metrics),
        **extra,
        "unix_time": time.time(),
    }
    validate_artifact(payload)
    out = pathlib.Path(path) if path is not None else artifact_path(area)
    out.write_text(json.dumps(payload, indent=2) + "\n")
    return out


def main(argv: list[str]) -> int:
    """CLI validator: ``python -m benchmarks._artifact BENCH_*.json``."""
    if not argv:
        print("usage: python -m benchmarks._artifact BENCH_<area>.json ...",
              file=sys.stderr)
        return 2
    bad = 0
    for name in argv:
        p = pathlib.Path(name)
        try:
            payload = validate_artifact(json.loads(p.read_text()))
        except (OSError, json.JSONDecodeError, ArtifactError) as e:
            print(f"{p}: INVALID -- {e}")
            bad += 1
            continue
        print(f"{p}: ok (benchmark={payload['benchmark']}, "
              f"{len(payload['metrics'])} metrics)")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
