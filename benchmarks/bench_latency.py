"""Figure 8 reproduction: scheduling latency -- Arnold's MILP vs exact
enumeration.  The paper: enumeration needs 30 s at 14 nodes in the simple
topology and 100 s+ at 10 nodes in the medium one, while the MILP schedules
a 512-node job in a 1000+-node cluster at interactive latency.
"""

import itertools
import time

import numpy as np

from repro.core import (Cluster, JobSpec, ModelSpec, ScheduleRequest,
                        build_comm_matrix, get_scheduler)
from repro.core.mip import _counts_objective

MODEL7B = ModelSpec(
    name="gpt-7b", hidden=4096, layers=32, vocab=50304, seq_len=2048,
    global_batch=1024, micro_batch=1, d_ff=16384,
)


def enumerate_optimal(group_size: int, m: int, free: np.ndarray, alpha: float,
                      beta: float, deadline: float = 30.0):
    """Exact DFS over per-group pod allocations (the paper's enumeration
    baseline).  Returns (objective, seconds, timed_out)."""
    k = len(free)
    t0 = time.perf_counter()
    best = [np.inf]
    # all ways to split one group of `group_size` nodes over k pods
    def splits(remaining, pods_left):
        if pods_left == 1:
            yield (remaining,)
            return
        for take in range(remaining + 1):
            for rest in splits(remaining - take, pods_left - 1):
                yield (take,) + rest

    all_splits = [s for s in splits(group_size, k)]
    counts = np.zeros((m, k), dtype=int)
    used = np.zeros(k, dtype=int)
    timed_out = [False]

    def dfs(i):
        if time.perf_counter() - t0 > deadline:
            timed_out[0] = True
            return
        if i == m:
            best[0] = min(best[0], _counts_objective(counts, alpha, beta))
            return
        for s in all_splits:
            arr = np.array(s)
            if ((used + arr) <= free).all():
                counts[i] = arr
                used[:] += arr
                dfs(i + 1)
                used[:] -= arr
                if timed_out[0]:
                    return
        counts[i] = 0

    dfs(0)
    return best[0], time.perf_counter() - t0, timed_out[0]


def run() -> list[tuple]:
    rows = []
    # enumeration blow-up on setting (i)-like topology
    free3 = np.array([6.0, 6.0, 6.0])
    for m in (2, 4, 6):
        obj, dt, to = enumerate_optimal(2, m, free3, 0.3, 0.7, deadline=20.0)
        rows.append((f"latency_enumeration_{m * 2}nodes_s", dt * 1e6,
                     round(dt, 3) if not to else "timeout"))
    # Arnold MILP latency across job scales on the big cluster
    cluster = Cluster.paper_setting("iii")
    for n_nodes, tp, pp in ((16, 8, 8), (64, 8, 8), (368, 8, 8), (512, 8, 8)):
        dp = n_nodes * 8 // tp // pp
        comm = build_comm_matrix(JobSpec(n_gpus=n_nodes * 8, tp=tp, pp=pp, model=MODEL7B))
        t0 = time.perf_counter()
        res = get_scheduler("mip").schedule(
            ScheduleRequest(comm=comm, cluster=cluster, alpha=0.3)
        )
        dt = time.perf_counter() - t0
        rows.append((f"latency_arnold_{n_nodes}nodes_ms", dt * 1e6,
                     round(dt * 1e3, 1)))
    rows.append(("paper_claim_512node_subsecond_ok", 0.0, int(dt < 1.0)))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
