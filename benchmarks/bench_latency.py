"""Figure 8 reproduction + the scale tier (DESIGN.md §8): scheduling
latency of exact enumeration vs Arnold's flat MILP vs the hierarchical
``"hier"`` tier.

The paper: enumeration needs 30 s at 14 nodes in the simple topology and
100 s+ at 10 nodes in the medium one, while the MILP schedules a 512-node
job in a 1000+-node cluster at interactive latency.  The scale tier goes
beyond the paper: on a ~10k-node cluster every ``"hier"`` solve must fit a
1 s budget, a warm-start re-solve after a single-node failure must beat
the cold solve by a wide margin, and the placement cache must hit on a
recurring job shape.  Results are snapshotted to
``BENCH_sched_latency.json`` through the shared artifact API --
the scheduler side's cross-PR perf baseline.

``run(smoke=True)`` (CI) shrinks the cluster and skips the enumeration
blow-up but exercises every scale-tier path and still writes the artifact.
"""

import itertools
import pathlib
import sys
import time

if __package__ in (None, ""):  # script mode: python benchmarks/bench_latency.py
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))

import numpy as np

from benchmarks._artifact import artifact_path, write_bench
from repro.core import (Cluster, JobSpec, ModelSpec, ScheduleRequest,
                        build_comm_matrix, get_scheduler, weighted_spread)
from repro.core.mip import _counts_objective

BENCH_FILE = artifact_path("sched_latency")

MODEL7B = ModelSpec(
    name="gpt-7b", hidden=4096, layers=32, vocab=50304, seq_len=2048,
    global_batch=1024, micro_batch=1, d_ff=16384,
)

ALPHA = 0.3
SOLVER_BUDGET_S = 1.0

# Paper-setting parity jobs: (setting, n_gpus, tp, pp) sized to fit each
# Table 1 cluster subset.
PARITY_JOBS = (("i", 96, 4, 2), ("ii", 2048, 8, 8), ("iii", 4096, 8, 8))


def enumerate_optimal(group_size: int, m: int, free: np.ndarray, alpha: float,
                      beta: float, deadline: float = 30.0):
    """Exact DFS over per-group pod allocations (the paper's enumeration
    baseline).  Returns (objective, seconds, timed_out)."""
    k = len(free)
    t0 = time.perf_counter()
    best = [np.inf]
    # all ways to split one group of `group_size` nodes over k pods
    def splits(remaining, pods_left):
        if pods_left == 1:
            yield (remaining,)
            return
        for take in range(remaining + 1):
            for rest in splits(remaining - take, pods_left - 1):
                yield (take,) + rest

    all_splits = [s for s in splits(group_size, k)]
    counts = np.zeros((m, k), dtype=int)
    used = np.zeros(k, dtype=int)
    timed_out = [False]

    def dfs(i):
        if time.perf_counter() - t0 > deadline:
            timed_out[0] = True
            return
        if i == m:
            best[0] = min(best[0], _counts_objective(counts, alpha, beta))
            return
        for s in all_splits:
            arr = np.array(s)
            if ((used + arr) <= free).all():
                counts[i] = arr
                used[:] += arr
                dfs(i + 1)
                used[:] -= arr
                if timed_out[0]:
                    return
        counts[i] = 0

    dfs(0)
    return best[0], time.perf_counter() - t0, timed_out[0]


def _schedule(name: str, comm, cluster, **req_kw):
    req = ScheduleRequest(comm=comm, cluster=cluster, alpha=ALPHA, **req_kw)
    t0 = time.perf_counter()
    res = get_scheduler(name).schedule(req)
    return res, time.perf_counter() - t0


def _scale_tier_rows(smoke: bool) -> tuple[list[tuple], dict, dict]:
    """Scale-tier measurements; returns (csv rows, workload, metrics)."""
    rows: list[tuple] = []
    metrics: dict = {}

    if smoke:
        n_pods, nodes_per_pod = 32, 32          # 1024 nodes
        job = JobSpec(n_gpus=1024, tp=8, pp=8, model=MODEL7B)   # 128 nodes
    else:
        n_pods, nodes_per_pod = 104, 96         # 9984 nodes ("10k-node")
        job = JobSpec(n_gpus=4096, tp=8, pp=8, model=MODEL7B)   # 512 nodes
    cluster = Cluster.uniform(n_pods, nodes_per_pod)
    comm = build_comm_matrix(job)

    # Cold hierarchical solve under the 1 s budget.
    cold, cold_wall = _schedule("hier", comm, cluster,
                                time_budget=SOLVER_BUDGET_S)
    rows.append((f"latency_hier_cold_{cluster.n_nodes}nodes_ms",
                 cold_wall * 1e6, round(cold_wall * 1e3, 2)))
    metrics["hier_cold_s"] = cold.solve_seconds
    metrics["hier_cold_subsecond"] = int(cold.solve_seconds < SOLVER_BUDGET_S)
    metrics["hier_blocks_touched"] = cold.stats["blocks_touched"]
    metrics["hier_weighted_spread"] = weighted_spread(cold.placement, ALPHA)

    # Flat MILP on the same cluster, for the latency comparison row.
    flat, flat_wall = _schedule("mip", comm, cluster)
    rows.append((f"latency_mip_flat_{cluster.n_nodes}nodes_ms",
                 flat_wall * 1e6, round(flat_wall * 1e3, 2)))
    metrics["mip_flat_s"] = flat.solve_seconds
    metrics["flat_weighted_spread"] = weighted_spread(flat.placement, ALPHA)

    # Warm-start re-solve after a single-node failure.
    victim = cold.placement.node_ids()[0]
    warm, _ = _schedule(
        "hier", comm, cluster, time_budget=SOLVER_BUDGET_S,
        prev_placement=cold.placement,
        dirty_nodes=frozenset([victim]),
        excluded_nodes=frozenset([victim]),
    )
    speedup = cold.solve_seconds / max(warm.solve_seconds, 1e-9)
    rows.append(("latency_hier_warm_ms", warm.solve_seconds * 1e6,
                 round(warm.solve_seconds * 1e3, 3)))
    rows.append(("latency_warm_speedup_x", 0.0, round(speedup, 1)))
    metrics["hier_warm_s"] = warm.solve_seconds
    metrics["warm_speedup_x"] = speedup
    metrics["warm_used_repair"] = int(warm.method == "hier-warm")

    # Placement cache: the same job shape again must hit.
    rerun, _ = _schedule("hier", comm, cluster, time_budget=SOLVER_BUDGET_S)
    metrics["cache_hit_on_rerun"] = int(rerun.method == "hier-cached")
    metrics["cache_hit_rate"] = rerun.stats["cache"]["hit_rate"]
    rows.append(("latency_cache_hit_on_rerun", 0.0,
                 metrics["cache_hit_on_rerun"]))

    # Paper-setting parity: hier weighted spread vs flat mip (target: <=1.1x).
    worst_ratio = 0.0
    for which, n_gpus, tp, pp in PARITY_JOBS:
        pcomm = build_comm_matrix(JobSpec(n_gpus=n_gpus, tp=tp, pp=pp,
                                          model=MODEL7B))
        pm, _ = _schedule("mip", pcomm, Cluster.paper_setting(which))
        ph, _ = _schedule("hier", pcomm, Cluster.paper_setting(which))
        sm = weighted_spread(pm.placement, ALPHA)
        sh = weighted_spread(ph.placement, ALPHA)
        ratio = sh / max(sm, 1e-9)
        worst_ratio = max(worst_ratio, ratio)
        rows.append((f"spread_parity_hier_vs_mip_{which}", 0.0,
                     round(ratio, 3)))
    metrics["spread_parity_worst_ratio"] = worst_ratio

    workload = {
        "n_minipods": n_pods,
        "nodes_per_minipod": nodes_per_pod,
        "n_cluster_nodes": cluster.n_nodes,
        "job_nodes": job.n_nodes,
        "comm_shape": f"{comm.n_rows}x{comm.n_cols}",
        "alpha": ALPHA,
        "solver_budget_s": SOLVER_BUDGET_S,
        "free_signature_head": str(cluster.free_signature(8)[:4]),
        "smoke": smoke,
    }
    return rows, workload, metrics


def run(smoke: bool = False) -> list[tuple]:
    rows = []
    if not smoke:
        # enumeration blow-up on setting (i)-like topology
        free3 = np.array([6.0, 6.0, 6.0])
        for m in (2, 4, 6):
            obj, dt, to = enumerate_optimal(2, m, free3, 0.3, 0.7, deadline=20.0)
            rows.append((f"latency_enumeration_{m * 2}nodes_s", dt * 1e6,
                         round(dt, 3) if not to else "timeout"))
        # Arnold MILP latency across job scales on the big cluster
        cluster = Cluster.paper_setting("iii")
        for n_nodes, tp, pp in ((16, 8, 8), (64, 8, 8), (368, 8, 8), (512, 8, 8)):
            comm = build_comm_matrix(
                JobSpec(n_gpus=n_nodes * 8, tp=tp, pp=pp, model=MODEL7B))
            res, dt = _schedule("mip", comm, cluster)
            rows.append((f"latency_arnold_{n_nodes}nodes_ms", dt * 1e6,
                         round(dt * 1e3, 1)))
        rows.append(("paper_claim_512node_subsecond_ok", 0.0, int(dt < 1.0)))

    scale_rows, workload, metrics = _scale_tier_rows(smoke)
    rows.extend(scale_rows)
    write_bench("sched_latency", workload=workload, metrics=metrics)
    rows.append(("latency_wrote_bench_json", 0.0, int(BENCH_FILE.exists())))
    return rows


if __name__ == "__main__":
    for r in run(smoke="--smoke" in sys.argv):
        print(",".join(str(x) for x in r))
