"""Render the §Roofline table from reports/dryrun.jsonl (deliverable g).

Reads the dry-run sweep output and emits (a) CSV rows for benchmarks/run.py
and (b) a markdown table written to reports/roofline.md that EXPERIMENTS.md
§Roofline embeds.
"""

import json
import pathlib

REPORTS = pathlib.Path(__file__).resolve().parent.parent / "reports"


def rederive(rec: dict) -> dict:
    """Rebuild the roofline terms of a dry-run record with the analytic HBM
    model (records store raw HLO totals, so no recompile is needed; records
    written before the analytic model was added get upgraded here)."""
    from repro.configs import SHAPES, get_config
    from repro.launch.roofline import Roofline, analytic_hbm_bytes

    if rec.get("status") != "ok" or "roofline" not in rec:
        return rec
    rl = rec["roofline"]
    cfg = get_config(rec["arch"])
    shape = SHAPES[rec["shape"]]
    opts = rec.get("overrides", {}).get("opts", {})
    attn_impl = opts.get("attn_impl",
                         "chunked" if rec["shape"] == "prefill_32k" else "xla")
    cache_bytes = 0.0
    if shape.kind == "decode":
        # decode arguments per device x chips ~ cache size (params excluded
        # by subtracting their footprint is noisy; use argument bytes)
        arg = rec["memory"].get("argument_bytes_per_device") or 0
        cache_bytes = float(arg) * rl["chips"] * 0.5  # cache read dominates
    analytic = analytic_hbm_bytes(
        cfg, shape, microbatches=rec.get("microbatches", 1),
        attn_impl=attn_impl, remat=opts.get("remat", True),
        kv_cache_bytes=cache_bytes,
    )
    new = Roofline(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
        chips=rl["chips"], hlo_flops=rl["hlo_flops"], hlo_bytes=rl["hlo_bytes"],
        collective_bytes=rl["collective_bytes"], collectives=rl["collectives"],
        model_flops=rl["model_flops"], analytic_bytes=analytic,
    )
    rec = dict(rec)
    rec["roofline"] = new.to_dict()
    return rec

NEXT_MOVE = {
    # one sentence per dominant term on what would move it down
    "compute": "raise arithmetic efficiency: larger per-device batch or fused kernels",
    "memory": "cut HBM traffic: fuse elementwise chains, avoid remat re-reads, bf16 master",
    "collective": "cut wire bytes: reduce FSDP regather frequency, overlap or compress collectives",
}


def load(path=None):
    path = path or REPORTS / "dryrun.jsonl"
    recs = []
    if not pathlib.Path(path).exists():
        return recs
    by_key = {}
    for line in open(path):
        line = line.strip()
        if line:
            r = json.loads(line)
            by_key[(r["arch"], r["shape"], r["mesh"])] = r  # keep last
    return [rederive(r) for r in by_key.values()]


def render_markdown(recs) -> str:
    lines = [
        "| arch | shape | chips | compute_s | memory_s | collective_s |"
        " dominant | MODEL/HLO flops | roofline frac | next move |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("status") != "ok" or "roofline" not in r or r["mesh"] != "single":
            continue
        rl = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {rl['chips']} "
            f"| {rl['compute_s']:.2e} | {rl['memory_s']:.2e} "
            f"| {rl['collective_s']:.2e} | {rl['dominant']} "
            f"| {rl['useful_ratio']:.2f} | {rl['roofline_fraction']:.2f} "
            f"| {NEXT_MOVE[rl['dominant']]} |"
        )
    skipped = [r for r in recs if str(r.get("status", "")).startswith("skipped")]
    if skipped:
        lines.append("")
        lines.append("Skipped cells (see DESIGN.md §4):")
        for r in sorted({(s["arch"], s["shape"]) for s in skipped}):
            lines.append(f"- {r[0]} x {r[1]}")
    return "\n".join(lines)


def run() -> list[tuple]:
    recs = load()
    rows = []
    ok = [r for r in recs if r.get("status") == "ok"]
    failed = [r for r in recs if str(r.get("status", "")).startswith("FAILED")]
    skipped = [r for r in recs if str(r.get("status", "")).startswith("skipped")]
    rows.append(("dryrun_cells_ok", 0.0, len(ok)))
    rows.append(("dryrun_cells_failed", 0.0, len(failed)))
    rows.append(("dryrun_cells_skipped_documented", 0.0, len(skipped)))
    singles = [r for r in ok if r["mesh"] == "single" and "roofline" in r]
    for r in singles:
        rl = r["roofline"]
        rows.append((
            f"roofline_{r['arch']}_{r['shape']}_dominant", 0.0, rl["dominant"],
        ))
        rows.append((
            f"roofline_{r['arch']}_{r['shape']}_fraction", 0.0,
            round(rl["roofline_fraction"], 3),
        ))
    if recs:
        md = render_markdown(recs)
        (REPORTS / "roofline.md").write_text(md)
        rows.append(("roofline_markdown_written", 0.0, 1))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
