"""Render EXPERIMENTS.md §Dry-run table from reports/dryrun.jsonl."""

import json
import pathlib

REPORTS = pathlib.Path(__file__).resolve().parent.parent / "reports"


def main():
    recs = [json.loads(l) for l in open(REPORTS / "dryrun.jsonl") if l.strip()]
    cells = {}
    for r in recs:
        cells[(r["arch"], r["shape"], r["mesh"])] = r  # keep last on re-runs
    recs = list(cells.values())
    archs = sorted({r["arch"] for r in recs})
    shapes = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
    print("| arch | shape | single: status / peak GiB/dev / compile s | "
          "multi: status / peak GiB/dev / compile s |")
    print("|---|---|---|---|")
    for a in archs:
        for s in shapes:
            row = []
            for mesh in ("single", "multi"):
                r = cells.get((a, s, mesh))
                if r is None:
                    row.append("(pending)")
                elif r["status"] == "ok":
                    peak = (r["memory"]["peak_bytes_per_device"] or 0) / 2**30
                    row.append(f"ok / {peak:.2f} / {r['compile_s']:.0f}")
                elif r["status"].startswith("skipped"):
                    row.append("skip (full-attn @512k)")
                else:
                    row.append("FAILED")
            print(f"| {a} | {s} | {row[0]} | {row[1]} |")
    n_ok = sum(1 for r in recs if r["status"] == "ok")
    n_skip = sum(1 for r in recs if str(r["status"]).startswith("skipped"))
    n_fail = sum(1 for r in recs if str(r["status"]).startswith("FAILED"))
    print(f"\nok={n_ok} skipped={n_skip} failed={n_fail} total={len(recs)}")


if __name__ == "__main__":
    main()
