"""Figure 7 / Table 1 reproduction: weighted max spread of communication
groups, Arnold's MILP vs best-fit / random-fit / gpu-packing / topo-aware on
benchmark settings (i)(ii)(iii), sweeping the affinity alpha.

Paper claims: up to 1.67x lower than the best baseline, 1.2x on average; all
algorithms tie on the simple setting (i).  We also run fragmented-cluster
variants (random 35% occupancy), which exercise the true MILP path.

``--fabric {clos,rail-only,torus,dragonfly,all}`` re-runs the comparison on
a capacity-matched fabric of that family (DESIGN.md §9); the default (no
flag) is the paper's CLOS setting, bit-identical to the pre-fabric numbers.
"""

import sys
import time

import numpy as np

from repro.core import (
    Cluster,
    JobSpec,
    ModelSpec,
    ScheduleRequest,
    build_comm_matrix,
    get_scheduler,
    list_schedulers,
    weighted_spread,
)
from repro.topo import comparable_fabric, list_fabrics

MODEL7B = ModelSpec(
    name="gpt-7b", hidden=4096, layers=32, vocab=50304, seq_len=2048,
    global_batch=1024, micro_batch=1, d_ff=16384,
)
SETTINGS = {"i": (12, 4, 2), "ii": (24, 4, 8), "iii": (46, 8, 8)}
ALPHAS = (0.0, 0.1, 0.3, 0.5)


def _cluster_for(setting: str, fabric: "str | None") -> Cluster:
    """Paper-setting cluster, optionally rebuilt on another fabric family
    with the same per-domain capacities (``None`` = legacy CLOS path)."""
    cluster = Cluster.paper_setting(setting)
    if fabric is None:
        return cluster
    caps = [p.capacity for p in cluster.minipods]
    return Cluster.from_fabric(comparable_fabric(fabric, caps))


def _one(setting: str, alpha: float, fragment: float, seed: int = 0,
         fabric: "str | None" = None):
    dp, tp, pp = SETTINGS[setting]
    cluster = _cluster_for(setting, fabric)
    if fragment:
        rng = np.random.default_rng(seed)
        job_nodes = dp * tp * pp // 8
        max_busy = cluster.n_nodes - job_nodes
        busy = rng.choice(
            cluster.n_nodes, size=min(int(fragment * cluster.n_nodes), max_busy),
            replace=False,
        )
        cluster.allocate([int(b) for b in busy])
    comm = build_comm_matrix(JobSpec(n_gpus=dp * tp * pp, tp=tp, pp=pp, model=MODEL7B))
    request = ScheduleRequest(comm=comm, cluster=cluster, alpha=alpha, seed=seed)
    ours = weighted_spread(get_scheduler("mip").schedule(request).placement, alpha)
    base = {}
    for name in list_schedulers():
        if name in ("mip", "hier"):  # Arnold-family tiers are not baselines
            continue
        try:
            base[name] = weighted_spread(
                get_scheduler(name).schedule(request).placement, alpha
            )
        except Exception:
            base[name] = float("inf")
    best = min(base.values())
    return ours, base, best


def run(fabric: "str | None" = None) -> list[tuple]:
    tag = "" if fabric is None else f"{fabric}_"
    rows = []
    ratios = []
    for setting in SETTINGS:
        for alpha in ALPHAS:
            t0 = time.perf_counter()
            ours, base, best = _one(setting, alpha, fragment=0.0, fabric=fabric)
            dt = (time.perf_counter() - t0) * 1e6
            rows.append((f"spread_{tag}{setting}_a{alpha}_arnold", dt, round(ours, 3)))
            rows.append((f"spread_{tag}{setting}_a{alpha}_bestbaseline", dt, round(best, 3)))
            if ours > 0:
                ratios.append(best / ours)
            elif best > 0:
                ratios.append(2.0)  # we hit 0, baseline didn't: cap the ratio
            else:
                ratios.append(1.0)
    # fragmented variants (MILP path)
    for setting in ("ii", "iii"):
        for alpha in (0.1, 0.3):
            t0 = time.perf_counter()
            ours, base, best = _one(setting, alpha, fragment=0.35, fabric=fabric)
            dt = (time.perf_counter() - t0) * 1e6
            rows.append((f"spread_frag_{tag}{setting}_a{alpha}_arnold", dt, round(ours, 3)))
            rows.append((f"spread_frag_{tag}{setting}_a{alpha}_bestbaseline", dt, round(best, 3)))
            if ours > 0:
                ratios.append(best / ours)
    rows.append((f"spread_{tag}mean_improvement_x", 0.0, round(float(np.mean(ratios)), 3)))
    rows.append((f"spread_{tag}max_improvement_x", 0.0, round(float(np.max(ratios)), 3)))
    if fabric is None:
        rows.append(("paper_claim_avg_1.2x_ok", 0.0, int(np.mean(ratios) >= 1.15)))
    return rows


if __name__ == "__main__":
    args = sys.argv[1:]
    fabrics: "list[str | None]" = [None]
    if "--fabric" in args:
        which = args[args.index("--fabric") + 1]
        fabrics = list(list_fabrics()) if which == "all" else [which]
    for f in fabrics:
        for r in run(fabric=f):
            print(",".join(str(x) for x in r))
