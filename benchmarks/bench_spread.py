"""Figure 7 / Table 1 reproduction: weighted max spread of communication
groups, Arnold's MILP vs best-fit / random-fit / gpu-packing / topo-aware on
benchmark settings (i)(ii)(iii), sweeping the affinity alpha.

Paper claims: up to 1.67x lower than the best baseline, 1.2x on average; all
algorithms tie on the simple setting (i).  We also run fragmented-cluster
variants (random 35% occupancy), which exercise the true MILP path.
"""

import time

import numpy as np

from repro.core import (
    Cluster,
    JobSpec,
    ModelSpec,
    ScheduleRequest,
    build_comm_matrix,
    get_scheduler,
    list_schedulers,
    weighted_spread,
)

MODEL7B = ModelSpec(
    name="gpt-7b", hidden=4096, layers=32, vocab=50304, seq_len=2048,
    global_batch=1024, micro_batch=1, d_ff=16384,
)
SETTINGS = {"i": (12, 4, 2), "ii": (24, 4, 8), "iii": (46, 8, 8)}
ALPHAS = (0.0, 0.1, 0.3, 0.5)


def _one(setting: str, alpha: float, fragment: float, seed: int = 0):
    dp, tp, pp = SETTINGS[setting]
    cluster = Cluster.paper_setting(setting)
    if fragment:
        rng = np.random.default_rng(seed)
        job_nodes = dp * tp * pp // 8
        max_busy = cluster.n_nodes - job_nodes
        busy = rng.choice(
            cluster.n_nodes, size=min(int(fragment * cluster.n_nodes), max_busy),
            replace=False,
        )
        cluster.allocate([int(b) for b in busy])
    comm = build_comm_matrix(JobSpec(n_gpus=dp * tp * pp, tp=tp, pp=pp, model=MODEL7B))
    request = ScheduleRequest(comm=comm, cluster=cluster, alpha=alpha, seed=seed)
    ours = weighted_spread(get_scheduler("mip").schedule(request).placement, alpha)
    base = {}
    for name in list_schedulers():
        if name in ("mip", "hier"):  # Arnold-family tiers are not baselines
            continue
        try:
            base[name] = weighted_spread(
                get_scheduler(name).schedule(request).placement, alpha
            )
        except Exception:
            base[name] = float("inf")
    best = min(base.values())
    return ours, base, best


def run() -> list[tuple]:
    rows = []
    ratios = []
    for setting in SETTINGS:
        for alpha in ALPHAS:
            t0 = time.perf_counter()
            ours, base, best = _one(setting, alpha, fragment=0.0)
            dt = (time.perf_counter() - t0) * 1e6
            rows.append((f"spread_{setting}_a{alpha}_arnold", dt, round(ours, 3)))
            rows.append((f"spread_{setting}_a{alpha}_bestbaseline", dt, round(best, 3)))
            if ours > 0:
                ratios.append(best / ours)
            elif best > 0:
                ratios.append(2.0)  # we hit 0, baseline didn't: cap the ratio
            else:
                ratios.append(1.0)
    # fragmented variants (MILP path)
    for setting in ("ii", "iii"):
        for alpha in (0.1, 0.3):
            t0 = time.perf_counter()
            ours, base, best = _one(setting, alpha, fragment=0.35)
            dt = (time.perf_counter() - t0) * 1e6
            rows.append((f"spread_frag_{setting}_a{alpha}_arnold", dt, round(ours, 3)))
            rows.append((f"spread_frag_{setting}_a{alpha}_bestbaseline", dt, round(best, 3)))
            if ours > 0:
                ratios.append(best / ours)
    rows.append(("spread_mean_improvement_x", 0.0, round(float(np.mean(ratios)), 3)))
    rows.append(("spread_max_improvement_x", 0.0, round(float(np.max(ratios)), 3)))
    rows.append(("paper_claim_avg_1.2x_ok", 0.0, int(np.mean(ratios) >= 1.15)))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
