"""Serving benchmark: seeded load-gen run through the continuous-batching
engine (DESIGN.md §7), emitting the repo's first cross-PR perf baseline
file ``BENCH_serve.json`` (tokens/sec, p50/p99 latency, batch occupancy).

The workload (seed 0) is fully reproducible -- the engine's
batching-invariance means the generated tokens are identical across runs
and machines; the latencies are the measured quantity.
"""

import json
import time
from pathlib import Path

import jax

from repro.configs import get_config
from repro.models import ModelOptions, build_model
from repro.serve import (
    EngineConfig,
    LengthMixture,
    LoadGenConfig,
    ServeEngine,
    generate_requests,
    run_benchmark,
)

BENCH_FILE = Path(__file__).resolve().parent.parent / "BENCH_serve.json"

LOAD = LoadGenConfig(
    seed=0,
    n_requests=12,
    rate_rps=200.0,
    prompt_mix=LengthMixture(((4, 0.5), (8, 0.3), (16, 0.2))),
    response_mix=LengthMixture(((8, 0.6), (16, 0.4))),
    vocab=512,
)

ENGINE = EngineConfig(max_batch=6, page_size=8, n_pages=48, max_blocks=4)


def run_serve(write_json: bool = True):
    cfg = get_config("glm4-9b").reduced()
    model = build_model(cfg, ModelOptions(compute_dtype="float32", remat=False))
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, ENGINE)
    requests = generate_requests(LOAD)
    report = run_benchmark(engine, requests)
    engine.cache.allocator.assert_all_free()  # page-recycling invariant

    payload = {
        "schema": 1,
        "benchmark": "serve",
        "workload": {
            "seed": LOAD.seed,
            "n_requests": LOAD.n_requests,
            "rate_rps": LOAD.rate_rps,
            "model": cfg.name + "-reduced",
            "total_tokens": report.total_tokens,  # seed-determined
        },
        "engine": {
            "max_batch": ENGINE.max_batch,
            "page_size": ENGINE.page_size,
            "n_pages": ENGINE.n_pages,
        },
        "metrics": report.to_dict(),
        "unix_time": time.time(),
    }
    if write_json:
        BENCH_FILE.write_text(json.dumps(payload, indent=2) + "\n")
    return report, payload


def run() -> list[tuple]:
    report, _ = run_serve()
    ms = 1e3  # derived column in ms where latency, else native unit
    return [
        ("serve_tokens_per_s", 0.0, round(report.tokens_per_s, 1)),
        ("serve_goodput_tokens_per_s", 0.0, round(report.goodput_tokens_per_s, 1)),
        ("serve_total_tokens", 0.0, report.total_tokens),
        ("serve_ttft_p50", report.ttft_p50_ms * ms, round(report.ttft_p50_ms, 2)),
        ("serve_ttft_p99", report.ttft_p99_ms * ms, round(report.ttft_p99_ms, 2)),
        ("serve_per_token_p50", report.per_token_p50_ms * ms,
         round(report.per_token_p50_ms, 2)),
        ("serve_per_token_p99", report.per_token_p99_ms * ms,
         round(report.per_token_p99_ms, 2)),
        ("serve_e2e_p50", report.e2e_p50_ms * ms, round(report.e2e_p50_ms, 2)),
        ("serve_e2e_p99", report.e2e_p99_ms * ms, round(report.e2e_p99_ms, 2)),
        ("serve_mean_batch_occupancy", 0.0,
         round(report.mean_batch_occupancy, 2)),
        ("serve_wrote_bench_json", 0.0, int(BENCH_FILE.exists())),
    ]


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
