"""Serving benchmark: seeded load-gen run through the continuous-batching
engine (DESIGN.md §7), emitting the cross-PR perf baseline
``BENCH_serve.json`` (tokens/sec, p50/p99 latency, batch occupancy)
through the shared artifact API (:mod:`benchmarks._artifact`).

The workload (seed 0) is fully reproducible -- the engine's
batching-invariance means the generated tokens are identical across runs
and machines; the latencies are the measured quantity.
"""

import pathlib
import sys

if __package__ in (None, ""):  # script mode: python benchmarks/bench_serve.py
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))

import jax

from benchmarks._artifact import artifact_path, write_bench
from repro.configs import get_config
from repro.models import ModelOptions, build_model
from repro.serve import (
    EngineConfig,
    LengthMixture,
    LoadGenConfig,
    ServeEngine,
    generate_requests,
    run_benchmark,
)

BENCH_FILE = artifact_path("serve")

LOAD = LoadGenConfig(
    seed=0,
    n_requests=12,
    rate_rps=200.0,
    prompt_mix=LengthMixture(((4, 0.5), (8, 0.3), (16, 0.2))),
    response_mix=LengthMixture(((8, 0.6), (16, 0.4))),
    vocab=512,
)

SMOKE_LOAD = LoadGenConfig(
    seed=0,
    n_requests=4,
    rate_rps=200.0,
    prompt_mix=LengthMixture(((4, 0.7), (8, 0.3))),
    response_mix=LengthMixture(((8, 1.0),)),
    vocab=512,
)

ENGINE = EngineConfig(max_batch=6, page_size=8, n_pages=48, max_blocks=4)


def run_serve(write_json: bool = True, smoke: bool = False):
    load = SMOKE_LOAD if smoke else LOAD
    cfg = get_config("glm4-9b").reduced()
    model = build_model(cfg, ModelOptions(compute_dtype="float32", remat=False))
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, ENGINE)
    requests = generate_requests(load)
    report = run_benchmark(engine, requests)
    engine.cache.allocator.assert_all_free()  # page-recycling invariant

    payload_path = None
    if write_json:
        payload_path = write_bench(
            "serve",
            workload={
                "seed": load.seed,
                "n_requests": load.n_requests,
                "rate_rps": load.rate_rps,
                "model": cfg.name + "-reduced",
                "total_tokens": report.total_tokens,  # seed-determined
                "smoke": smoke,
            },
            metrics=report.to_dict(),
            engine={
                "max_batch": ENGINE.max_batch,
                "page_size": ENGINE.page_size,
                "n_pages": ENGINE.n_pages,
            },
        )
    return report, payload_path


def run(smoke: bool = False) -> list[tuple]:
    report, _ = run_serve(smoke=smoke)
    ms = 1e3  # derived column in ms where latency, else native unit
    return [
        ("serve_tokens_per_s", 0.0, round(report.tokens_per_s, 1)),
        ("serve_goodput_tokens_per_s", 0.0, round(report.goodput_tokens_per_s, 1)),
        ("serve_total_tokens", 0.0, report.total_tokens),
        ("serve_ttft_p50", report.ttft_p50_ms * ms, round(report.ttft_p50_ms, 2)),
        ("serve_ttft_p99", report.ttft_p99_ms * ms, round(report.ttft_p99_ms, 2)),
        ("serve_per_token_p50", report.per_token_p50_ms * ms,
         round(report.per_token_p50_ms, 2)),
        ("serve_per_token_p99", report.per_token_p99_ms * ms,
         round(report.per_token_p99_ms, 2)),
        ("serve_e2e_p50", report.e2e_p50_ms * ms, round(report.e2e_p50_ms, 2)),
        ("serve_e2e_p99", report.e2e_p99_ms * ms, round(report.e2e_p99_ms, 2)),
        ("serve_mean_batch_occupancy", 0.0,
         round(report.mean_batch_occupancy, 2)),
        ("serve_wrote_bench_json", 0.0, int(BENCH_FILE.exists())),
    ]


if __name__ == "__main__":
    for r in run(smoke="--smoke" in sys.argv):
        print(",".join(str(x) for x in r))
