"""Load-generator tests (DESIGN.md §7.3): seeded determinism, Poisson
arrival statistics, mixture sampling, report math, and (slow tier) a full
load-gen benchmark run through a real engine."""

import dataclasses

import numpy as np
import pytest

from repro.serve import (
    GenerationResult,
    LengthMixture,
    LoadGenConfig,
    ServeReport,
    generate_requests,
)
from repro.serve.engine import EngineStats


class TestGenerateRequests:
    def test_same_seed_same_workload(self):
        a = generate_requests(LoadGenConfig(seed=42, n_requests=20))
        b = generate_requests(LoadGenConfig(seed=42, n_requests=20))
        assert a == b

    def test_different_seed_differs(self):
        a = generate_requests(LoadGenConfig(seed=0, n_requests=20))
        b = generate_requests(LoadGenConfig(seed=1, n_requests=20))
        assert a != b

    def test_poisson_arrivals_monotone_and_rate_shaped(self):
        cfg = LoadGenConfig(seed=0, n_requests=400, rate_rps=50.0)
        reqs = generate_requests(cfg)
        arr = np.array([r.arrival_s for r in reqs])
        assert (np.diff(arr) >= 0).all() and arr[0] > 0
        mean_gap = float(np.diff(np.concatenate([[0.0], arr])).mean())
        assert 1 / 50.0 / 2 < mean_gap < 1 / 50.0 * 2

    def test_lengths_come_from_mixtures(self):
        cfg = LoadGenConfig(
            seed=3, n_requests=50,
            prompt_mix=LengthMixture(((4, 1.0), (6, 1.0))),
            response_mix=LengthMixture(((2, 1.0),)),
        )
        reqs = generate_requests(cfg)
        assert {len(r.prompt) for r in reqs} <= {4, 6}
        assert {r.max_new_tokens for r in reqs} == {2}

    def test_tokens_within_vocab(self):
        reqs = generate_requests(LoadGenConfig(seed=0, n_requests=10, vocab=32))
        assert all(0 <= t < 32 for r in reqs for t in r.prompt)

    def test_bad_mixture_rejected(self):
        with pytest.raises(ValueError):
            LengthMixture(())
        with pytest.raises(ValueError):
            LengthMixture(((0, 1.0),))


def _result(rid, arrival, admitted, times):
    return GenerationResult(
        request_id=rid, prompt=(1, 2), tokens=[0] * len(times),
        arrival_s=arrival, admitted_s=admitted, finished_s=times[-1],
        token_times_s=list(times),
    )


class TestServeReport:
    def test_metrics_from_synthetic_run(self):
        # two requests: token cadence 10 ms and 20 ms, TTFT 5 ms and 30 ms
        results = [
            _result(0, 0.0, 0.001, [0.005, 0.015, 0.025]),
            _result(1, 0.01, 0.02, [0.04, 0.06]),
        ]
        stats = EngineStats(decode_steps=3, prefills=2, tokens_generated=5,
                            elapsed_s=0.1, occupancy=[1, 2, 1])
        report = ServeReport.from_run(results, stats)
        assert report.total_tokens == 5
        assert report.tokens_per_s == pytest.approx(50.0)
        assert report.goodput_tokens_per_s == pytest.approx(50.0)
        assert report.ttft_p50_ms == pytest.approx(17.5)  # median of 5, 30
        assert report.per_token_p50_ms == pytest.approx(10.0)  # 10,10,20 ms
        assert report.e2e_p50_ms == pytest.approx(37.5)  # 25 ms, 50 ms
        assert report.mean_batch_occupancy == pytest.approx(4 / 3)

    def test_report_round_trips_to_dict(self):
        report = ServeReport.from_run([], EngineStats())
        d = report.to_dict()
        assert set(d) == {f.name for f in dataclasses.fields(ServeReport)}
        assert "tok/s" in report.summary()


@pytest.mark.slow
def test_loadgen_benchmark_end_to_end():
    """Full seeded load-gen benchmark against a real engine (slow tier):
    Poisson arrivals admitted mid-flight, report populated, pages freed."""
    import jax

    from repro.configs import get_config
    from repro.models import ModelOptions, build_model
    from repro.serve import EngineConfig, ServeEngine, run_benchmark

    cfg = get_config("glm4-9b").reduced()
    model = build_model(cfg, ModelOptions(compute_dtype="float32", remat=False))
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, EngineConfig(
        max_batch=4, page_size=8, n_pages=48, max_blocks=8))
    load = LoadGenConfig(seed=0, n_requests=12, rate_rps=100.0, vocab=cfg.vocab)
    report = run_benchmark(engine, generate_requests(load))

    assert report.n_completed == 12
    assert report.total_tokens == sum(
        r.max_new_tokens for r in generate_requests(load))
    assert report.tokens_per_s > 0
    assert report.per_token_p99_ms >= report.per_token_p50_ms >= 0
    assert report.e2e_p99_ms >= report.e2e_p50_ms > 0
    assert 1.0 <= report.mean_batch_occupancy <= 4.0
    engine.cache.allocator.assert_all_free()
