"""Tests for the four baseline schedulers (§7.1)."""

import numpy as np
import pytest

from repro.core import (
    ALL_BASELINES,
    Cluster,
    Infeasible,
    JobSpec,
    best_fit,
    build_comm_matrix,
    gpu_packing,
    max_spreads,
    random_fit,
    topo_aware,
)
from repro.core.baselines import _fm_bipartition, _job_graph


class TestAllBaselines:
    @pytest.mark.parametrize("name", list(ALL_BASELINES))
    def test_valid_placement(self, name, small_comm, cluster_i):
        p = ALL_BASELINES[name](small_comm, cluster_i)
        ids = p.node_ids()
        assert len(ids) == small_comm.n_cells
        assert len(set(ids)) == len(ids)
        assert all(cluster_i.is_free(n) for n in ids)

    @pytest.mark.parametrize("name", list(ALL_BASELINES))
    def test_infeasible_raises(self, name, small_comm):
        tiny = Cluster.uniform(2, 2)  # 4 nodes < 12 needed
        with pytest.raises(Infeasible):
            ALL_BASELINES[name](small_comm, tiny)

    def test_best_fit_prefers_fullest_pod(self, small_comm):
        cluster = Cluster([12, 30])
        p = best_fit(small_comm, cluster)
        pods = p.minipod_of()
        assert (pods == 0).all()  # 12 cells exactly fill the smaller pod

    def test_gpu_packing_prefers_largest_pod(self, small_comm):
        cluster = Cluster([12, 30])
        p = gpu_packing(small_comm, cluster)
        assert (p.minipod_of() == 1).all()

    def test_random_fit_is_seeded_deterministic(self, small_comm, cluster_i):
        p1 = random_fit(small_comm, cluster_i, seed=7)
        p2 = random_fit(small_comm, cluster_i, seed=7)
        assert (p1.assignment == p2.assignment).all()

    def test_random_fit_balances(self, small_comm):
        cluster = Cluster.uniform(3, 8)
        p = random_fit(small_comm, cluster, seed=0)
        pods, counts = np.unique(p.minipod_of(), return_counts=True)
        assert len(pods) == 3 and counts.max() - counts.min() <= 1


class TestTopoAware:
    def test_job_graph_edges(self, small_comm):
        adj = _job_graph(small_comm)
        assert len(adj) == small_comm.n_cells
        # PP chain edge between (0,0)-(0,1)
        ids = small_comm.cell_ids()
        assert ids[0, 1] in adj[ids[0, 0]]
        # DP ring edge between (0,0)-(1,0)
        assert ids[1, 0] in adj[ids[0, 0]]

    def test_fm_respects_sizes(self, small_comm):
        adj = _job_graph(small_comm)
        verts = list(adj)
        a, b = _fm_bipartition(adj, verts, size_a=5)
        assert len(a) == 5 and len(b) == len(verts) - 5
        assert set(a) | set(b) == set(verts)

    def test_fm_finds_obvious_cut(self):
        # Two 4-cliques joined by one light edge: FM should cut the bridge.
        adj = {i: {} for i in range(8)}
        for grp in (range(4), range(4, 8)):
            for i in grp:
                for j in grp:
                    if i != j:
                        adj[i][j] = 10.0
        adj[3][4] = adj[4][3] = 0.1
        # adversarial initial split: interleaved
        verts = [0, 4, 1, 5, 2, 6, 3, 7]
        a, b = _fm_bipartition(adj, verts, size_a=4)
        assert set(a) in ({0, 1, 2, 3}, {4, 5, 6, 7})

    def test_topo_aware_groups_pp_chains(self, model7b):
        """With dominant PP edge weight, topo-aware should co-locate rows."""
        cluster = Cluster.uniform(4, 4)
        job = JobSpec(n_gpus=8 * 8, tp=4, pp=4, model=model7b)  # 4x4 matrix
        comm = build_comm_matrix(job)
        p = topo_aware(comm, cluster)
        dp_s, pp_s = max_spreads(p)
        assert pp_s <= 2  # chains mostly intact
