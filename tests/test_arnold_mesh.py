"""Integration: Arnold placement -> device permutation -> JAX mesh, and the
on-mesh spread verification (the JAX-side analogue of Eq. 3)."""

import json
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import (
    Cluster,
    JobSpec,
    ModelSpec,
    ScheduleRequest,
    build_comm_matrix,
    device_permutation,
    get_scheduler,
    logical_to_physical_gpus,
)

MODEL = ModelSpec(name="m", hidden=1024, layers=8, vocab=5000, seq_len=128,
                  global_batch=64, d_ff=4096)


class TestRankAssign:
    def test_permutation_is_bijection(self):
        cluster = Cluster.uniform(4, 4)
        comm = build_comm_matrix(JobSpec(n_gpus=64, tp=4, pp=2, model=MODEL))
        res = get_scheduler("mip").schedule(
            ScheduleRequest(comm=comm, cluster=cluster, alpha=0.3))
        perm = device_permutation(res.placement, tp=4)
        assert sorted(perm) == sorted(
            g for n in res.placement.node_ids() for g in range(n * 8, n * 8 + 8)
        )

    def test_tp_stays_intra_node(self):
        """TP ranks of any (pp, dp) pair must map to the same physical node
        (the paper's §2 invariant: TP on NVLink only)."""
        cluster = Cluster.uniform(4, 4)
        comm = build_comm_matrix(JobSpec(n_gpus=64, tp=4, pp=2, model=MODEL))
        res = get_scheduler("mip").schedule(
            ScheduleRequest(comm=comm, cluster=cluster, alpha=0.3))
        phys = logical_to_physical_gpus(res.placement, tp=4)
        nodes = phys // 8
        assert (nodes == nodes[..., :1]).all()

    def test_dp_groups_align_to_pods(self):
        """With alpha=1 (pure DP consolidation) on an ample cluster, every
        DP group should land inside one minipod."""
        cluster = Cluster.uniform(2, 12)
        comm = build_comm_matrix(JobSpec(n_gpus=96, tp=4, pp=2, model=MODEL))
        res = get_scheduler("mip").schedule(
            ScheduleRequest(comm=comm, cluster=cluster, alpha=1.0, unit="dp"))
        phys = logical_to_physical_gpus(res.placement, tp=4)  # (pp, dp, tp)
        pods = phys // (8 * 12)
        for c in range(phys.shape[0]):
            assert len(np.unique(pods[c])) == 1, f"DP group of stage {c} spans pods"


class TestArnoldMeshOnDevices:
    def test_arnold_mesh_reduces_spread(self):
        """On 64 fake devices (4 pods x 16), a fragmented cluster forces the
        naive id-order mesh to split communication groups across pods;
        the Arnold-ordered mesh must not be worse on the model axis."""
        code = textwrap.dedent("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=64"
            import json
            import jax
            from repro.core import (Cluster, JobSpec, ModelSpec, ScheduleRequest,
                                    build_comm_matrix, get_scheduler)
            from repro.launch.mesh import make_arnold_mesh, mesh_group_spread

            cluster = Cluster.uniform(4, 2)  # 4 pods x 2 nodes (16 devs/pod)
            model = ModelSpec(name="m", hidden=1024, layers=8, vocab=5000,
                              seq_len=128, global_batch=64, d_ff=4096)
            comm = build_comm_matrix(JobSpec(n_gpus=64, tp=8, pp=2, model=model))
            res = get_scheduler("mip").schedule(
                ScheduleRequest(comm=comm, cluster=cluster, alpha=0.0))
            mesh = make_arnold_mesh(res.placement, tp=8, shape=(8, 8),
                                    axes=("data", "model"))
            naive = jax.make_mesh((8, 8), ("data", "model"))
            out = {
                "arnold_model": mesh_group_spread(mesh, "model", 16),
                "naive_model": mesh_group_spread(naive, "model", 16),
                "arnold_data": mesh_group_spread(mesh, "data", 16),
                "naive_data": mesh_group_spread(naive, "data", 16),
            }
            print(json.dumps(out))
        """)
        proc = subprocess.run(
            [sys.executable, "-c", code], capture_output=True, text=True,
            timeout=600, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
            cwd="/root/repo",
        )
        assert proc.returncode == 0, proc.stderr
        out = json.loads(proc.stdout.strip().splitlines()[-1])
        # TP (model axis) groups always stay intra-node -> spread 1
        assert out["arnold_model"] == 1
        assert out["arnold_data"] <= out["naive_data"]
