"""Unit + property tests for the spread metric (Eq. 2/3)."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need it; skip, not error
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import Cluster, Placement, max_spreads, weighted_spread
from repro.core.spread import distance_onehot, group_spread, mean_spreads


def onehot(assignments, k):
    v = np.zeros((len(assignments), k))
    v[np.arange(len(assignments)), assignments] = 1
    return v


class TestDistanceOnehot:
    def test_identical_vectors_distance_zero(self):
        assert distance_onehot(onehot([2, 2, 2], 5)) == 0

    def test_two_pods_distance_two(self):
        # Eq. 3: positions 0 and 1 both differ somewhere -> D = 2.
        assert distance_onehot(onehot([0, 1], 3)) == 2

    def test_three_pods(self):
        assert distance_onehot(onehot([0, 1, 2], 4)) == 3

    def test_rejects_bad_shape(self):
        with pytest.raises(ValueError):
            distance_onehot(np.zeros(3))


class TestGroupSpread:
    @given(st.lists(st.integers(0, 7), min_size=1, max_size=32))
    @settings(max_examples=200, deadline=None)
    def test_matches_onehot_distance(self, pods):
        """group_spread is exactly Eq. 3 evaluated on one-hot encodings."""
        assert group_spread(np.array(pods)) == distance_onehot(onehot(pods, 8))

    @given(st.lists(st.integers(0, 7), min_size=1, max_size=32))
    @settings(max_examples=100, deadline=None)
    def test_bounds(self, pods):
        s = group_spread(np.array(pods))
        assert 0 <= s <= len(set(pods))
        assert (s == 0) == (len(set(pods)) == 1)

    @given(st.lists(st.integers(0, 7), min_size=2, max_size=32), st.integers(0, 7))
    @settings(max_examples=100, deadline=None)
    def test_monotone_under_consolidation(self, pods, target):
        """Moving every member into one pod never increases spread."""
        before = group_spread(np.array(pods))
        after = group_spread(np.array([target] * len(pods)))
        assert after <= before or before == 0


class TestPlacement:
    def test_shape_validation(self, small_comm, cluster_i):
        with pytest.raises(ValueError):
            Placement(small_comm, np.arange(4).reshape(2, 2), cluster_i)

    def test_duplicate_node_rejected(self, small_comm, cluster_i):
        a = np.zeros(small_comm.shape, dtype=int)  # all cells -> node 0
        with pytest.raises(ValueError):
            Placement(small_comm, a, cluster_i)

    def test_single_pod_zero_spread(self, small_comm):
        cluster = Cluster.uniform(1, 32)
        a = np.arange(small_comm.n_cells).reshape(small_comm.shape)
        p = Placement(small_comm, a, cluster)
        assert max_spreads(p) == (0, 0)
        assert weighted_spread(p, 0.5) == 0.0

    def test_weighted_spread_requires_alpha_beta_sum_one(self, small_comm, cluster_i):
        a = np.arange(small_comm.n_cells).reshape(small_comm.shape)
        p = Placement(small_comm, a, cluster_i)
        with pytest.raises(ValueError):
            weighted_spread(p, 0.5, 0.7)

    def test_known_spread(self, small_comm):
        """6x2 matrix, rows 0-2 in pod 0, rows 3-5 in pod 1: PP groups local
        (spread 0), DP groups span both pods (spread 2)."""
        cluster = Cluster.uniform(2, 12)
        a = np.array([[0, 1], [2, 3], [4, 5], [12, 13], [14, 15], [16, 17]])
        p = Placement(small_comm, a, cluster)
        dp_s, pp_s = max_spreads(p)
        assert (dp_s, pp_s) == (2, 0)
        assert weighted_spread(p, alpha=1.0, beta=0.0) == 2.0
        assert weighted_spread(p, alpha=0.0, beta=1.0) == 0.0
        dpm, ppm = mean_spreads(p)
        assert dpm == 2.0 and ppm == 0.0
