"""Shared fixtures.  NOTE: XLA_FLAGS / device-count overrides are deliberately
NOT set here -- smoke tests and benches must see the single real CPU device.
Multi-device tests spawn subprocesses with their own XLA_FLAGS."""

import numpy as np
import pytest

from repro.core import Cluster, JobSpec, ModelSpec, build_comm_matrix


@pytest.fixture
def model7b():
    # 7B GPT-style reference model (paper Appendix C sanity numbers).
    return ModelSpec(
        name="gpt-7b", hidden=4096, layers=32, vocab=50304, seq_len=2048,
        global_batch=1024, micro_batch=1, d_ff=16384,
    )


@pytest.fixture
def small_job(model7b):
    return JobSpec(n_gpus=96, tp=4, pp=2, model=model7b)


@pytest.fixture
def small_comm(small_job):
    return build_comm_matrix(small_job)


@pytest.fixture
def cluster_i():
    return Cluster.paper_setting("i")


@pytest.fixture
def cluster_iii():
    return Cluster.paper_setting("iii")
