"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp ref oracles,
swept over shapes and dtypes, plus hypothesis property tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need it; skip, not error
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention as fa_pallas
from repro.kernels.rmsnorm import rmsnorm as rms_pallas
from repro.kernels.ssd_chunk import ssd_chunk_scan as ssd_pallas


def rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


TOL = {jnp.float32: (1e-5, 1e-5), jnp.bfloat16: (2e-2, 2e-2)}


class TestFlashAttention:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize(
        "b,hq,hkv,sq,skv,hd,bq,bk",
        [
            (2, 4, 2, 64, 64, 32, 32, 32),
            (1, 8, 1, 128, 128, 64, 64, 32),   # MQA
            (2, 4, 4, 96, 96, 32, 32, 32),     # MHA, non-pow2 seq
            (1, 2, 2, 32, 128, 32, 32, 64),    # cross-length (prefix cache)
        ],
    )
    def test_matches_ref_causal(self, dtype, b, hq, hkv, sq, skv, hd, bq, bk):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = rand(ks[0], (b, hq, sq, hd), dtype)
        k = rand(ks[1], (b, hkv, skv, hd), dtype)
        v = rand(ks[2], (b, hkv, skv, hd), dtype)
        out = fa_pallas(q, k, v, causal=True, block_q=bq, block_k=bk, interpret=True)
        expect = ref.flash_attention_ref(q, k, v, causal=True)
        rtol, atol = TOL[dtype]
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(expect, np.float32),
            rtol=rtol, atol=atol,
        )

    def test_non_causal(self):
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        q = rand(ks[0], (1, 2, 64, 32), jnp.float32)
        k = rand(ks[1], (1, 2, 64, 32), jnp.float32)
        v = rand(ks[2], (1, 2, 64, 32), jnp.float32)
        out = fa_pallas(q, k, v, causal=False, block_q=32, block_k=32, interpret=True)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref.flash_attention_ref(q, k, v, causal=False)),
            rtol=1e-5, atol=1e-5,
        )

    def test_causal_mask_is_exact(self):
        """Future tokens must have exactly zero influence."""
        ks = jax.random.split(jax.random.PRNGKey(2), 3)
        q = rand(ks[0], (1, 2, 64, 32), jnp.float32)
        k = rand(ks[1], (1, 2, 64, 32), jnp.float32)
        v = rand(ks[2], (1, 2, 64, 32), jnp.float32)
        out1 = fa_pallas(q, k, v, causal=True, block_q=32, block_k=32, interpret=True)
        # perturb the last key/value: only the last query may change
        k2 = k.at[:, :, -1].add(100.0)
        v2 = v.at[:, :, -1].add(100.0)
        out2 = fa_pallas(q, k2, v2, causal=True, block_q=32, block_k=32, interpret=True)
        np.testing.assert_allclose(
            np.asarray(out1[:, :, :-1]), np.asarray(out2[:, :, :-1]), rtol=1e-6, atol=1e-6
        )

    @given(
        sq=st.sampled_from([32, 64, 96]),
        hd=st.sampled_from([16, 32]),
        group=st.sampled_from([1, 2, 4]),
    )
    @settings(max_examples=10, deadline=None)
    def test_property_rows_sum_to_one(self, sq, hd, group):
        """With v = all-ones, output must be exactly ones (softmax rows sum
        to 1) for every causal block pattern."""
        ks = jax.random.split(jax.random.PRNGKey(3), 2)
        q = rand(ks[0], (1, 2 * group, sq, hd), jnp.float32)
        k = rand(ks[1], (1, 2, sq, hd), jnp.float32)
        v = jnp.ones((1, 2, sq, hd), jnp.float32)
        out = fa_pallas(q, k, v, causal=True, block_q=32, block_k=32, interpret=True)
        np.testing.assert_allclose(np.asarray(out), 1.0, rtol=1e-5, atol=1e-5)


class TestRMSNorm:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("shape", [(4, 128), (2, 16, 256), (512,), (3, 5, 7, 64)])
    def test_matches_ref(self, dtype, shape):
        ks = jax.random.split(jax.random.PRNGKey(0), 2)
        x = rand(ks[0], shape, dtype)
        scale = rand(ks[1], (shape[-1],), jnp.float32) + 1.0
        out = rms_pallas(x, scale, interpret=True, block_rows=64)
        expect = ref.rmsnorm_ref(x, scale)
        rtol, atol = TOL[dtype]
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(expect, np.float32),
            rtol=rtol, atol=atol,
        )

    @given(rows=st.integers(1, 64), d=st.sampled_from([32, 128]))
    @settings(max_examples=15, deadline=None)
    def test_property_unit_rms(self, rows, d):
        """With scale=1, output rows have RMS 1 (up to eps)."""
        x = jax.random.normal(jax.random.PRNGKey(rows), (rows, d)) * 5.0
        out = rms_pallas(x, jnp.ones((d,)), interpret=True, block_rows=16)
        rms = np.sqrt(np.mean(np.asarray(out) ** 2, axis=-1))
        np.testing.assert_allclose(rms, 1.0, rtol=1e-3)


class TestSSDChunk:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("b,H,s,P,N,chunk", [
        (2, 2, 64, 16, 8, 16),
        (1, 4, 128, 32, 16, 32),
        (2, 1, 32, 8, 8, 32),   # single chunk
    ])
    def test_matches_ref(self, dtype, b, H, s, P, N, chunk):
        ks = jax.random.split(jax.random.PRNGKey(0), 5)
        x = rand(ks[0], (b, H, s, P), dtype)
        B = rand(ks[1], (b, H, s, N), dtype) * 0.5
        C = rand(ks[2], (b, H, s, N), dtype) * 0.5
        dt = jax.nn.softplus(rand(ks[3], (b, H, s), jnp.float32))
        loga = -jax.nn.softplus(rand(ks[4], (b, H, s), jnp.float32))  # decay < 1
        y_pl, S_pl = ssd_pallas(x, B, C, dt, loga, chunk=chunk, interpret=True)
        y_rf, S_rf = ops.ssd_chunk_scan(x, B, C, dt, loga, chunk=chunk, impl="ref")
        rtol, atol = TOL[dtype]
        np.testing.assert_allclose(
            np.asarray(y_pl, np.float32), np.asarray(y_rf, np.float32),
            rtol=rtol, atol=max(atol, 1e-4),
        )
        np.testing.assert_allclose(
            np.asarray(S_pl), np.asarray(S_rf), rtol=1e-4, atol=1e-4
        )

    def test_matches_model_time_scan(self):
        """SSD chunk kernel == per-timestep recurrence (ground truth)."""
        b, H, s, P, N = 1, 2, 24, 8, 4
        ks = jax.random.split(jax.random.PRNGKey(7), 5)
        x = rand(ks[0], (b, H, s, P), jnp.float32)
        B = rand(ks[1], (b, H, s, N), jnp.float32) * 0.5
        C = rand(ks[2], (b, H, s, N), jnp.float32) * 0.5
        dt = jax.nn.softplus(rand(ks[3], (b, H, s), jnp.float32))
        loga = -jax.nn.softplus(rand(ks[4], (b, H, s), jnp.float32))
        y_pl, S_pl = ssd_pallas(x, B, C, dt, loga, chunk=8, interpret=True)
        # per-step recurrence
        S = np.zeros((b, H, P, N), np.float32)
        ys = np.zeros((b, H, s, P), np.float32)
        xn, Bn, Cn = map(np.asarray, (x, B, C))
        dtn, logan = np.asarray(dt), np.asarray(loga)
        for t in range(s):
            a = np.exp(logan[:, :, t])[..., None, None]
            S = a * S + dtn[:, :, t][..., None, None] * np.einsum(
                "bhp,bhn->bhpn", xn[:, :, t], Bn[:, :, t]
            )
            ys[:, :, t] = np.einsum("bhpn,bhn->bhp", S, Cn[:, :, t])
        np.testing.assert_allclose(np.asarray(y_pl), ys, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(S_pl), S, rtol=1e-4, atol=1e-4)


class TestOpsDispatch:
    def test_auto_falls_back_to_ref_on_cpu(self):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = rand(ks[0], (1, 2, 32, 16), jnp.float32)
        k = rand(ks[1], (1, 2, 32, 16), jnp.float32)
        v = rand(ks[2], (1, 2, 32, 16), jnp.float32)
        out = ops.flash_attention(q, k, v)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref.flash_attention_ref(q, k, v)), rtol=1e-6
        )
