"""Tests for the unified scheduler API: request/result contract, registry,
and fallback chains (DESIGN.md §2.4)."""

import numpy as np
import pytest

from repro.core import (
    Cluster,
    FallbackChain,
    Infeasible,
    ScheduleRequest,
    ScheduleResult,
    Scheduler,
    get_scheduler,
    list_schedulers,
    max_spreads,
    register_scheduler,
    schedule_mip,
    weighted_spread,
)
from repro.core.scheduler import _REGISTRY

ALL_NAMES = ("mip", "best-fit", "random-fit", "gpu-packing", "topo-aware")


class TestRegistry:
    def test_builtins_registered(self):
        assert set(ALL_NAMES) <= set(list_schedulers())

    def test_name_normalization(self):
        assert get_scheduler("topo_aware") is get_scheduler("topo-aware")
        assert get_scheduler("MIP") is get_scheduler("mip")
        assert get_scheduler("milp") is get_scheduler("mip")  # alias

    def test_instance_passthrough(self):
        sched = get_scheduler("best-fit")
        assert get_scheduler(sched) is sched

    def test_unknown_name(self):
        with pytest.raises(KeyError, match="available"):
            get_scheduler("no-such-policy")

    def test_duplicate_registration_rejected(self):
        with pytest.raises(ValueError, match="already registered"):
            register_scheduler("mip", get_scheduler("mip"))

    def test_register_and_overwrite(self):
        probe = get_scheduler("best-fit")
        try:
            register_scheduler("probe-policy", probe)
            assert get_scheduler("probe_policy") is probe
            register_scheduler("probe-policy", get_scheduler("mip"), overwrite=True)
            assert get_scheduler("probe-policy") is get_scheduler("mip")
        finally:
            _REGISTRY.pop("probe-policy", None)

    def test_comma_spec_builds_chain(self):
        chain = get_scheduler("mip,topo_aware")
        assert isinstance(chain, FallbackChain)

    def test_all_registered_satisfy_protocol(self):
        for name in list_schedulers():
            assert isinstance(get_scheduler(name), Scheduler)


class TestContract:
    @pytest.mark.parametrize("name", ALL_NAMES)
    def test_schedule_returns_valid_result(self, name, small_comm, cluster_i):
        res = get_scheduler(name).schedule(
            ScheduleRequest(comm=small_comm, cluster=cluster_i, alpha=0.3)
        )
        assert isinstance(res, ScheduleResult)
        ids = res.placement.node_ids()
        assert len(ids) == small_comm.n_cells == len(set(ids))
        assert all(cluster_i.is_free(n) for n in ids)
        assert (res.dp_spread, res.pp_spread) == max_spreads(res.placement)
        assert res.method and res.solve_seconds >= 0.0
        assert res.n_pods_used() >= 1
        assert res.weighted_spread(0.3) == pytest.approx(
            weighted_spread(res.placement, 0.3)
        )

    @pytest.mark.parametrize("name", ALL_NAMES)
    def test_infeasible_raises(self, name, small_comm):
        tiny = Cluster.uniform(2, 2)  # 4 nodes < 12 needed
        with pytest.raises(Infeasible):
            get_scheduler(name).schedule(
                ScheduleRequest(comm=small_comm, cluster=tiny)
            )

    def test_bad_unit_rejected(self, small_comm, cluster_i):
        with pytest.raises(ValueError, match="unit"):
            ScheduleRequest(comm=small_comm, cluster=cluster_i, unit="tp")

    def test_resolved_beta_defaults_to_complement(self, small_comm, cluster_i):
        req = ScheduleRequest(comm=small_comm, cluster=cluster_i, alpha=0.3)
        assert req.resolved_beta() == pytest.approx(0.7)
        req = ScheduleRequest(comm=small_comm, cluster=cluster_i, alpha=0.3, beta=1.0)
        assert req.resolved_beta() == 1.0

    @pytest.mark.parametrize("name", ALL_NAMES)
    def test_excluded_nodes_respected_and_restored(self, name, small_comm):
        cluster = Cluster.uniform(4, 8)
        excluded = frozenset(range(8))  # all of minipod 0
        res = get_scheduler(name).schedule(ScheduleRequest(
            comm=small_comm, cluster=cluster, excluded_nodes=excluded,
        ))
        assert not (set(res.placement.node_ids()) & excluded)
        assert cluster.n_free == cluster.n_nodes  # mask fully undone

    def test_reserved_nodes_masked_like_excluded(self, small_comm):
        cluster = Cluster.uniform(4, 8)
        reserved = frozenset(range(8, 16))
        res = get_scheduler("best-fit").schedule(ScheduleRequest(
            comm=small_comm, cluster=cluster, reserved_nodes=reserved,
        ))
        assert not (set(res.placement.node_ids()) & reserved)
        assert cluster.n_free == cluster.n_nodes

    def test_masking_can_make_request_infeasible(self, small_comm):
        cluster = Cluster.uniform(2, 8)  # 16 nodes, need 12
        with pytest.raises(Infeasible):
            get_scheduler("mip").schedule(ScheduleRequest(
                comm=small_comm, cluster=cluster,
                excluded_nodes=frozenset(range(8)),
            ))
        assert cluster.n_free == cluster.n_nodes


class TestShimEquivalence:
    def test_schedule_mip_shim_matches_registry(self, small_comm, cluster_i):
        via_shim = schedule_mip(small_comm, cluster_i, alpha=0.3)
        via_api = get_scheduler("mip").schedule(
            ScheduleRequest(comm=small_comm, cluster=cluster_i, alpha=0.3)
        )
        assert (via_shim.placement.assignment == via_api.placement.assignment).all()
        assert via_shim.objective == pytest.approx(via_api.objective)
        assert via_shim.method == via_api.method
        assert via_shim.n_pods_used == via_api.stats["n_pods_used"]

    def test_random_fit_seed_vs_rng(self, small_comm, cluster_i):
        from repro.core import random_fit

        by_seed = random_fit(small_comm, cluster_i, seed=11)
        by_rng = random_fit(small_comm, cluster_i, rng=np.random.default_rng(11))
        via_api = get_scheduler("random-fit").schedule(
            ScheduleRequest(comm=small_comm, cluster=cluster_i, seed=11)
        )
        assert (by_seed.assignment == by_rng.assignment).all()
        assert (by_seed.assignment == via_api.placement.assignment).all()

    def test_random_fit_seeds_differ(self, small_comm, cluster_i):
        from repro.core import random_fit

        a = random_fit(small_comm, cluster_i, seed=0)
        b = random_fit(small_comm, cluster_i, seed=1)
        assert not (a.assignment == b.assignment).all()


class _AlwaysInfeasible:
    name = "always-infeasible"

    def schedule(self, request):
        raise Infeasible("synthetic failure")


class _Slow:
    """Valid result, delivered too late (real-world: MILP grinding past the
    scheduling-loop deadline)."""

    name = "slow"

    def __init__(self, delay: float):
        self._delay = delay

    def schedule(self, request):
        import time

        time.sleep(self._delay)
        return get_scheduler("topo-aware").schedule(request)


class TestFallbackChain:
    def test_degrades_to_next_link(self, small_comm, cluster_i):
        chain = FallbackChain(_AlwaysInfeasible(), "topo-aware")
        res = chain.schedule(ScheduleRequest(comm=small_comm, cluster=cluster_i))
        assert res.method == "topo-aware"
        assert res.stats["fallbacks"][0][0] == "always-infeasible"

    def test_mip_to_topo_aware_on_solver_failure(self, small_comm, cluster_i,
                                                 monkeypatch):
        """Acceptance scenario: ``FallbackChain("mip", "topo_aware")``
        degrades gracefully when the MILP is Infeasible (here: solver
        returns nothing within the time budget and the greedy incumbent is
        disabled)."""
        import types

        import repro.core.mip as mip_mod

        monkeypatch.setattr(
            mip_mod, "milp",
            lambda **kw: types.SimpleNamespace(x=None, status=1,
                                               message="time limit reached"),
        )
        chain = FallbackChain("mip", "topo_aware")
        req = ScheduleRequest(
            comm=small_comm, cluster=cluster_i, alpha=0.3, time_budget=0.001,
            options={"use_greedy_bound": False},
        )
        with pytest.raises(Infeasible):
            get_scheduler("mip").schedule(req)  # the first link alone fails
        res = chain.schedule(req)
        assert res.method == "topo-aware"
        assert res.stats["fallbacks"][0][0] == "mip"
        assert len(res.placement.node_ids()) == small_comm.n_cells

    def test_all_links_fail_raises_aggregate(self, small_comm):
        tiny = Cluster.uniform(2, 2)
        chain = FallbackChain("mip", "topo_aware")
        with pytest.raises(Infeasible, match="mip.*topo-aware"):
            chain.schedule(ScheduleRequest(comm=small_comm, cluster=tiny))

    def test_first_link_success_has_no_fallback_stats(self, small_comm, cluster_i):
        res = FallbackChain("mip", "topo-aware").schedule(
            ScheduleRequest(comm=small_comm, cluster=cluster_i, alpha=0.3)
        )
        assert "fallbacks" not in res.stats

    def test_winning_link_recorded_in_served_by(self, small_comm, cluster_i):
        res = FallbackChain("mip", "topo-aware").schedule(
            ScheduleRequest(comm=small_comm, cluster=cluster_i, alpha=0.3)
        )
        assert res.stats["served_by"] == "mip"

    def test_slow_link_overrun_falls_through(self, small_comm, cluster_i):
        """A link that returns after its remaining budget is spent is
        discarded; the chain degrades and records why."""
        chain = FallbackChain(_Slow(0.2), "topo-aware")
        res = chain.schedule(ScheduleRequest(
            comm=small_comm, cluster=cluster_i, alpha=0.3, time_budget=0.05,
        ))
        assert res.stats["served_by"] == "topo-aware"
        name, msg = res.stats["fallbacks"][0]
        assert name == "slow" and "time budget" in msg
        assert len(res.placement.node_ids()) == small_comm.n_cells

    def test_exhausted_budget_skips_middle_links(self, small_comm, cluster_i):
        """Once the chain budget is gone, middle links are skipped outright
        and only the final (cheapest) link still runs."""
        chain = FallbackChain(_Slow(0.2), "mip", "topo-aware")
        res = chain.schedule(ScheduleRequest(
            comm=small_comm, cluster=cluster_i, alpha=0.3, time_budget=0.05,
        ))
        assert res.stats["served_by"] == "topo-aware"
        names = [n for n, _ in res.stats["fallbacks"]]
        assert names == ["slow", "mip"]
        assert "exhausted" in res.stats["fallbacks"][1][1]

    def test_final_link_exempt_from_overrun(self, small_comm, cluster_i):
        """A late placement from the last link beats no placement."""
        res = FallbackChain(_Slow(0.2)).schedule(ScheduleRequest(
            comm=small_comm, cluster=cluster_i, alpha=0.3, time_budget=0.05,
        ))
        assert res.stats["served_by"] == "slow"

    def test_empty_chain_rejected(self):
        with pytest.raises(ValueError):
            FallbackChain()


class TestDeprecatedShims:
    def test_schedule_mip_warns(self, small_comm, cluster_i):
        with pytest.warns(DeprecationWarning, match="get_scheduler"):
            schedule_mip(small_comm, cluster_i, alpha=0.3)

    @pytest.mark.parametrize("shim", ["best_fit", "gpu_packing", "random_fit",
                                      "topo_aware"])
    def test_baseline_shims_warn(self, shim, small_comm, cluster_i):
        import repro.core.baselines as baselines

        with pytest.warns(DeprecationWarning, match="get_scheduler"):
            getattr(baselines, shim)(small_comm, cluster_i)


class TestQueueIntegration:
    def test_queue_policy_takes_scheduler_by_name(self, small_comm):
        from repro.core import QueuePolicy

        cluster = Cluster.uniform(4, 8)
        policy = QueuePolicy(cluster, scheduler="mip,topo-aware")
        res = policy.plan_lpj(small_comm, arrival=100.0, alpha=0.3)
        assert isinstance(res, ScheduleResult)
        assert len(policy.reserved_nodes()) == small_comm.n_cells

    def test_plan_lpj_per_call_override(self, small_comm):
        from repro.core import QueuePolicy

        cluster = Cluster.uniform(4, 8)
        policy = QueuePolicy(cluster)  # default "mip"
        res = policy.plan_lpj(small_comm, arrival=100.0, alpha=0.3,
                              scheduler="gpu-packing")
        assert res.method == "gpu-packing"

    def test_simulator_lpj_plan_carries_scheduler(self, small_comm):
        from repro.core import QueuePolicy, TraceSimulator

        cluster = Cluster.uniform(4, 8)
        policy = QueuePolicy(cluster)
        sim = TraceSimulator(policy, tick=60.0)
        res = sim.run([], t_end=300.0,
                      lpj_plan=(small_comm, 200.0, 0.3, "pp", "topo-aware"),
                      plan_at=0.0)
        assert len(res.lpj_nodes) == small_comm.n_cells
        assert policy.lpj.result.method == "topo-aware"
