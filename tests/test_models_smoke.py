"""Per-architecture smoke tests: REDUCED config of the same family, one
forward/train step + one decode step on CPU, asserting shapes and no NaNs.
The FULL configs are exercised only via the dry-run (deliverable f)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import ModelOptions, build_model
from repro.models.whisper import N_FRAMES

OPTS = ModelOptions(compute_dtype="float32", remat=False)


def tiny_batch(cfg, b=2, s=12, key=0):
    rng = np.random.default_rng(key)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(
            rng.normal(size=(b, cfg.n_patches, cfg.d_model)) * 0.1, jnp.float32
        )
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(b, 24, cfg.d_model)) * 0.1, jnp.float32
        )
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
class TestArchSmoke:
    def test_train_step(self, arch):
        cfg = get_config(arch).reduced()
        model = build_model(cfg, OPTS)
        params = model.init(jax.random.PRNGKey(0))
        batch = tiny_batch(cfg)

        @jax.jit
        def step(p, b):
            (loss, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(p, b)
            return loss, metrics, grads

        loss, metrics, grads = step(params, batch)
        assert loss.shape == ()
        assert bool(jnp.isfinite(loss)), f"{arch}: loss not finite"
        gnorm = jnp.sqrt(
            sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
        )
        assert bool(jnp.isfinite(gnorm)), f"{arch}: grad norm not finite"
        assert float(gnorm) > 0.0, f"{arch}: zero gradients"

    def test_forward_shapes(self, arch):
        cfg = get_config(arch).reduced()
        model = build_model(cfg, OPTS)
        params = model.init(jax.random.PRNGKey(0))
        batch = tiny_batch(cfg)
        logits, aux = jax.jit(model.forward)(params, batch)
        b, s = batch["tokens"].shape
        assert logits.shape == (b, s, cfg.vocab), (arch, logits.shape)
        assert bool(jnp.all(jnp.isfinite(logits))), f"{arch}: logits not finite"

    def test_decode_step(self, arch):
        cfg = get_config(arch).reduced()
        model = build_model(cfg, OPTS)
        params = model.init(jax.random.PRNGKey(0))
        b, max_len = 2, 16
        cache = model.init_cache(b, max_len)
        step = jax.jit(model.decode_step)
        tok = jnp.zeros((b, 1), jnp.int32)
        logits, cache = step(params, cache, tok)
        assert logits.shape == (b, 1, cfg.vocab)
        assert bool(jnp.all(jnp.isfinite(logits)))
        assert int(cache["index"]) == 1
        logits2, cache = step(params, cache, tok)
        assert int(cache["index"]) == 2


@pytest.mark.parametrize("arch", ["glm4-9b", "xlstm-350m", "zamba2-2.7b", "whisper-tiny"])
def test_train_decode_parity(arch):
    """Teacher-forced decode must reproduce the training-forward logits --
    the strongest correctness check tying both code paths together."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg, OPTS)
    params = model.init(jax.random.PRNGKey(1))
    b, s = 2, 8
    batch = tiny_batch(cfg, b=b, s=s, key=1)
    logits_full, _ = jax.jit(model.forward)(params, batch)

    cache = model.init_cache(b, s)
    if cfg.family == "audio":
        cache = jax.jit(model.prefill_cross)(params, cache, batch["frames"])
        cache = jax.tree.map(
            lambda a, b_: a if a.shape == b_.shape else a, cache, cache
        )
    step = jax.jit(model.decode_step)
    outs = []
    for t in range(s):
        lg, cache = step(params, cache, batch["tokens"][:, t : t + 1])
        outs.append(lg)
    logits_dec = jnp.concatenate(outs, axis=1)
    if cfg.family == "vlm":
        pytest.skip("vlm forward includes patch prefix; decode is text-only")
    np.testing.assert_allclose(
        np.asarray(logits_full), np.asarray(logits_dec), rtol=2e-4, atol=2e-4
    )


def test_param_count_sanity():
    """Analytic param_count should be within ~25% of actual init size for
    the reduced transformer families (used for MODEL_FLOPS roofline)."""
    for arch in ["granite-8b", "dbrx-132b"]:
        cfg = get_config(arch).reduced()
        model = build_model(cfg, OPTS)
        params = model.init(jax.random.PRNGKey(0))
        actual = sum(p.size for p in jax.tree.leaves(params))
        analytic = cfg.param_count()
        assert abs(actual - analytic) / actual < 0.25, (arch, actual, analytic)


def test_full_config_param_counts():
    """Full configs match their published scale (analytic; no allocation)."""
    expected = {
        "granite-8b": (7e9, 10e9),
        "minicpm-2b": (2e9, 3.2e9),
        "glm4-9b": (8e9, 11e9),
        "phi4-mini-3.8b": (3e9, 5e9),
        "dbrx-132b": (110e9, 150e9),
        "qwen3-moe-235b-a22b": (200e9, 260e9),
        "phi-3-vision-4.2b": (3.3e9, 5e9),
        "xlstm-350m": (0.25e9, 0.6e9),
        "whisper-tiny": (0.02e9, 0.08e9),
        "zamba2-2.7b": (2e9, 3.5e9),
    }
    for arch, (lo, hi) in expected.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]B"
    # MoE active params
    q = get_config("qwen3-moe-235b-a22b")
    assert q.active_param_count() < 0.2 * q.param_count()
