"""Multi-device distribution tests.  These re-exec python with
``--xla_force_host_platform_device_count=8`` so the main pytest process (and
all smoke tests) keep seeing exactly 1 device."""

import json
import subprocess
import sys
import textwrap

import pytest

BOOT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, json
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
"""


def run_py(body: str) -> dict:
    code = BOOT + textwrap.dedent(body)
    proc = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, timeout=900,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
        cwd="/root/repo",
    )
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    return json.loads(proc.stdout.strip().splitlines()[-1])


class TestShardingRules:
    def test_param_specs_resolve(self):
        out = run_py("""
            from repro.configs import get_config
            from repro.models import build_model, ModelOptions
            from repro.parallel.sharding import param_shardings, opt_shardings
            mesh = jax.make_mesh((2, 4), ("data", "model"))
            cfg = get_config("glm4-9b").reduced()
            model = build_model(cfg, ModelOptions())
            pshape = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
            ps = param_shardings(pshape, mesh)
            specs = {}
            import jax.tree_util as jtu
            for (path, s), (_, leaf) in zip(
                jtu.tree_flatten_with_path(ps)[0], jtu.tree_flatten_with_path(pshape)[0]
            ):
                key = "/".join(str(getattr(k, "key", k)) for k in path)
                specs[key] = str(s.spec)
            print(json.dumps(specs))
        """)
        assert "model" in out["layers/attn/wq"]
        assert "model" in out["embed/tokens"]
        # kv heads (4 reduced) divisible by model=4 -> sharded
        assert "model" in out["layers/attn/wk"]
        assert "model" not in out["final_norm/norm_scale"]
        assert "data" not in out["final_norm/norm_scale"]

    def test_kv_indivisible_degrades_to_replication(self):
        out = run_py("""
            from repro.parallel.sharding import resolve_spec, default_rules
            mesh = jax.make_mesh((2, 4), ("data", "model"))
            rules = default_rules(mesh.axis_names)
            s1 = resolve_spec(("batch", None, "kv_heads", None), (8, 128, 2, 64), mesh, rules)
            s2 = resolve_spec(("batch", None, "kv_heads", None), (8, 128, 4, 64), mesh, rules)
            print(json.dumps({"indiv": str(s1), "div": str(s2)}))
        """)
        assert "model" not in out["indiv"]
        assert "model" in out["div"]

    def test_sharded_train_step_matches_single_device(self):
        """The pjit-sharded train step must be numerically equivalent to the
        unsharded one (same loss after 3 steps)."""
        out = run_py("""
            from repro.configs import get_config
            from repro.models import build_model, ModelOptions
            from repro.optim import AdamWConfig, init_opt_state
            from repro.train import make_train_step
            from repro.parallel import sharding as shd
            from repro.data import SyntheticDataset

            cfg = get_config("minicpm-2b").reduced()
            model = build_model(cfg, ModelOptions(compute_dtype="float32", remat=False))
            ds = SyntheticDataset(cfg.vocab, 16, 8)
            opt = AdamWConfig(lr=1e-3)

            # single device
            params = model.init(jax.random.PRNGKey(0))
            state = init_opt_state(params)
            step1 = make_train_step(model, opt, donate=False)
            for i in range(3):
                batch = {k: jnp.asarray(v) for k, v in ds.batch(i).items()}
                params, state, m1 = step1(params, state, batch)

            # sharded
            mesh = jax.make_mesh((2, 4), ("data", "model"))
            params2 = model.init(jax.random.PRNGKey(0))
            state2 = init_opt_state(params2)
            with shd.activate(mesh):
                stepper = make_train_step(model, opt, mesh=mesh, donate=False)
                batch_shape = jax.eval_shape(lambda: {k: jnp.asarray(v) for k, v in ds.batch(0).items()})
                fn = stepper(batch_shape)
                for i in range(3):
                    batch = {k: jnp.asarray(v) for k, v in ds.batch(i).items()}
                    params2, state2, m2 = fn(params2, state2, batch)
            print(json.dumps({"l1": float(m1["loss"]), "l2": float(m2["loss"])}))
        """)
        assert abs(out["l1"] - out["l2"]) < 1e-4, out

    def test_zero1_opt_state_sharded_over_data(self):
        out = run_py("""
            from repro.parallel.sharding import opt_spec
            mesh = jax.make_mesh((2, 4), ("data", "model"))
            s = opt_spec("layers/attn/wq", (4, 512, 512), mesh)
            print(json.dumps({"spec": str(s)}))
        """)
        assert "data" in out["spec"] and "model" in out["spec"]


class TestPipelineParallel:
    def test_pp_matches_sequential(self):
        """GPipe shard_map pipeline == sequential stage application, fwd and
        grad; boundary traffic equals Eq. 13."""
        out = run_py("""
            from repro.parallel.pipeline import pipeline_forward, pp_boundary_bytes
            from jax.experimental.shard_map import shard_map
            from functools import partial

            S, m, mb, d = 4, 8, 2, 16
            mesh = jax.make_mesh((S,), ("stage",))
            key = jax.random.PRNGKey(0)
            Ws = jax.random.normal(key, (S, d, d)) * 0.3

            def stage_fn(W, x):
                return jnp.tanh(x @ W)

            pipe = pipeline_forward(stage_fn, S, "stage")

            def run_pp(Ws, x):
                def inner(Wl, x):
                    return pipe(Wl[0], x)
                return shard_map(inner, mesh=mesh,
                                 in_specs=(jax.sharding.PartitionSpec("stage"), jax.sharding.PartitionSpec()),
                                 out_specs=jax.sharding.PartitionSpec(),
                                 check_rep=False)(Ws, x)

            x = jax.random.normal(jax.random.PRNGKey(1), (m, mb, d))
            y_pp = run_pp(Ws, x)
            y_ref = x
            for i in range(S):
                y_ref = stage_fn(Ws[i], y_ref)

            err = float(jnp.max(jnp.abs(y_pp - y_ref)))

            # gradient flows through ppermute
            def loss_pp(Ws):
                return jnp.sum(run_pp(Ws, x) ** 2)
            def loss_ref(Ws):
                y = x
                for i in range(S):
                    y = stage_fn(Ws[i], y)
                return jnp.sum(y ** 2)
            g_pp = jax.grad(loss_pp)(Ws)
            g_ref = jax.grad(loss_ref)(Ws)
            gerr = float(jnp.max(jnp.abs(g_pp - g_ref)))
            vol = pp_boundary_bytes(mb, 1, d, m)
            print(json.dumps({"err": err, "gerr": gerr, "vol": vol}))
        """)
        assert out["err"] < 1e-5, out
        assert out["gerr"] < 1e-4, out
        assert out["vol"] == 2 * 2 * 1 * 16 * 8 * 2


class TestFlashDecoding:
    def test_seq_sharded_decode_matches_unsharded(self):
        """When KV heads don't divide the model axis, decode takes the
        flash-decoding path (seq-sharded partial attention).  Its logits
        must match the unsharded decode exactly."""
        out = run_py("""
            from repro.configs import get_config
            import dataclasses
            from repro.models import build_model, ModelOptions
            from repro.parallel import sharding as shd
            from repro.train.train_step import cache_shardings
            import numpy as np

            cfg = dataclasses.replace(
                get_config("glm4-9b").reduced(), n_heads=6, n_kv_heads=3,
                d_model=96, head_dim=16)
            model = build_model(cfg, ModelOptions(compute_dtype="float32",
                                                  remat=False))
            params = model.init(jax.random.PRNGKey(0))
            b, L = 4, 32
            toks = [jnp.full((b,1), t % cfg.vocab, jnp.int32) for t in range(5)]

            # reference: no mesh
            cache = model.init_cache(b, L)
            outs_ref = []
            for t in toks:
                lg, cache = jax.jit(model.decode_step)(params, cache, t)
                outs_ref.append(np.asarray(lg))

            # sharded: mesh (2,4); kv=3 % 4 != 0 -> seq-flash path
            mesh = jax.make_mesh((2, 4), ("data", "model"))
            with shd.activate(mesh):
                p_sh = shd.param_shardings(jax.eval_shape(
                    lambda: model.init(jax.random.PRNGKey(0))), mesh)
                cache2 = model.init_cache(b, L)
                c_sh = cache_shardings(jax.eval_shape(
                    lambda: model.init_cache(b, L)), mesh, model=model)
                step = jax.jit(model.decode_step,
                               in_shardings=(p_sh, c_sh, None),
                               out_shardings=(None, c_sh))
                params2 = jax.device_put(params, p_sh)
                cache2 = jax.device_put(cache2, c_sh)
                outs_sh = []
                for t in toks:
                    lg, cache2 = step(params2, cache2, t)
                    outs_sh.append(np.asarray(lg))
            err = max(float(np.abs(a - b).max())
                      for a, b in zip(outs_ref, outs_sh))
            print(json.dumps({"err": err}))
        """)
        assert out["err"] < 1e-4, out


class TestCompressedCollectives:
    def test_compressed_psum_close_to_exact(self):
        out = run_py("""
            from repro.parallel.collectives import compressed_psum_mean
            from jax.experimental.shard_map import shard_map
            mesh = jax.make_mesh((8,), ("data",))
            x = jax.random.normal(jax.random.PRNGKey(0), (8, 64))

            def f(scheme):
                def inner(x):
                    return compressed_psum_mean(x[0], "data", scheme)
                return shard_map(inner, mesh=mesh,
                                 in_specs=jax.sharding.PartitionSpec("data"),
                                 out_specs=jax.sharding.PartitionSpec(),
                                 check_rep=False)(x)
            exact = jnp.mean(x, 0)
            e16 = float(jnp.max(jnp.abs(f("fp16") - exact)))
            e8 = float(jnp.max(jnp.abs(f("int8") - exact)))
            print(json.dumps({"fp16": e16, "int8": e8}))
        """)
        assert out["fp16"] < 1e-2
        assert out["int8"] < 5e-2

    def test_error_feedback_unbiased(self):
        """Accumulated error feedback keeps the long-run mean of compressed
        grads equal to the true mean (within fp tolerance)."""
        out = run_py("""
            from repro.parallel.collectives import compress_with_feedback, init_error_feedback
            g = {"w": jnp.full((64,), 0.100048828125)}   # not fp16-representable
            res = init_error_feedback(g)
            total = jnp.zeros((64,))
            N = 64
            for _ in range(N):
                cg, res = compress_with_feedback(g, res, "int8")
                total = total + cg["w"]
            drift = float(jnp.max(jnp.abs(total / N - g["w"])))
            print(json.dumps({"drift": drift}))
        """)
        assert out["drift"] < 1e-3, out
