"""Unit tests for HLO collective parsing + roofline math, and a subprocess
smoke of one real dry-run cell (whisper-tiny, the smallest arch)."""

import json
import pathlib
import subprocess
import sys

import pytest

from repro.configs import SHAPES, get_config
from repro.launch.roofline import (
    Roofline,
    inner_scan_flops,
    model_flops_for,
    parse_collective_bytes,
)

HLO_SAMPLE = """
HloModule jit_step
  %ag = bf16[16,4096,128]{2,1,0} all-gather(bf16[1,4096,128]{2,1,0} %p), dims={0}
  %ar = f32[1024]{0} all-reduce(f32[1024]{0} %x), to_apply=%add
  %rs = bf16[2,512]{1,0} reduce-scatter(bf16[32,512]{1,0} %y), dimensions={0}
  %a2a = bf16[8,64,64]{2,1,0} all-to-all(bf16[8,64,64]{2,1,0} %z), dimensions={0}
  %cp = bf16[4,32]{1,0} collective-permute(bf16[4,32]{1,0} %w), source_target_pairs={{0,1}}
  %ags = (bf16[1,128]{1,0}, bf16[16,128]{1,0}) all-gather-start(bf16[1,128]{1,0} %q)
  %agd = bf16[16,128]{1,0} all-gather-done((bf16[1,128]{1,0}, bf16[16,128]{1,0}) %ags)
  %mm = f32[128,128]{1,0} dot(f32[128,128]{1,0} %a, f32[128,128]{1,0} %b)
"""


class TestParseCollectives:
    def test_kinds_and_bytes(self):
        out = parse_collective_bytes(HLO_SAMPLE)
        assert out["all-gather"] == 16 * 4096 * 128 * 2 + 16 * 128 * 2  # sync + async-done
        assert out["all-reduce"] == 1024 * 4
        assert out["reduce-scatter"] == 2 * 512 * 2
        assert out["all-to-all"] == 8 * 64 * 64 * 2
        assert out["collective-permute"] == 4 * 32 * 2

    def test_async_start_not_double_counted(self):
        out = parse_collective_bytes(HLO_SAMPLE)
        # only the -done result (16*128 bf16) counted for the async pair
        assert out["all-gather"] - 16 * 4096 * 128 * 2 == 16 * 128 * 2

    def test_non_collective_ignored(self):
        out = parse_collective_bytes("%mm = f32[8,8]{1,0} dot(%a, %b)")
        assert out == {}


class TestRooflineMath:
    def test_terms_and_dominant(self):
        rl = Roofline(
            arch="a", shape="s", mesh="single", chips=256,
            hlo_flops=256 * 197e12,          # exactly 1 s of compute
            hlo_bytes=256 * 819e9 * 0.5,     # 0.5 s of memory
            collective_bytes=256 * 4 * 50e9 * 2.0,  # 2 s of collectives
            collectives={}, model_flops=128 * 197e12,
        )
        assert rl.compute_s == pytest.approx(1.0)
        assert rl.memory_s == pytest.approx(0.5)
        assert rl.collective_s == pytest.approx(2.0)
        assert rl.dominant == "collective"
        assert rl.useful_ratio == pytest.approx(0.5)
        assert rl.roofline_fraction == pytest.approx(0.5)

    def test_model_flops_kinds(self):
        cfg = get_config("granite-8b")
        n = cfg.active_param_count()
        tr = model_flops_for(cfg, SHAPES["train_4k"])
        pf = model_flops_for(cfg, SHAPES["prefill_32k"])
        dc = model_flops_for(cfg, SHAPES["decode_32k"])
        assert tr == 6.0 * n * 256 * 4096
        assert pf == 2.0 * n * 32 * 32768
        assert dc == 2.0 * n * 128

    def test_inner_scan_corrections(self):
        assert inner_scan_flops(get_config("granite-8b"), SHAPES["train_4k"]) == 0
        assert inner_scan_flops(get_config("xlstm-350m"), SHAPES["train_4k"]) > 0
        assert inner_scan_flops(get_config("zamba2-2.7b"), SHAPES["train_4k"]) > 0
        assert inner_scan_flops(get_config("xlstm-350m"), SHAPES["decode_32k"]) == 0


@pytest.mark.slow
class TestDryrunCell:
    def test_whisper_decode_cell_compiles(self, tmp_path):
        """One real dry-run cell end to end in a 512-device subprocess."""
        proc = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun",
             "--arch", "whisper-tiny", "--shape", "decode_32k",
             "--mesh", "single", "--out", str(tmp_path)],
            capture_output=True, text=True, timeout=900,
            env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
            cwd="/root/repo",
        )
        assert proc.returncode == 0, proc.stderr[-2000:]
        rec = json.loads((tmp_path / "dryrun.jsonl").read_text().splitlines()[0])
        assert rec["status"] == "ok"
        assert rec["chips"] == 256
        assert rec["roofline"]["collective_bytes"] > 0
        assert rec["memory"]["peak_bytes_per_device"] < 16 * 2**30
