"""Tests for the automated characterization pipeline (§4 -> §5.2 DB)."""

import numpy as np
import pytest

from repro.core import CharacterizationDB, Cluster, JobSpec, ModelSpec
from repro.core.characterize import characterize, characterize_sweep


def dense_model(layers=32, h=4096):
    return ModelSpec(name=f"dense-{layers}L", hidden=h, layers=layers,
                     vocab=50304, seq_len=2048, global_batch=512,
                     micro_batch=1, d_ff=4 * h)


def moe_model():
    return ModelSpec(name="moe", hidden=4096, layers=32, vocab=50304,
                     seq_len=2048, global_batch=512, micro_batch=1,
                     n_experts=16, top_k=4, d_expert=8192)


class TestCharacterize:
    def test_pp_wins_for_deep_pipelines(self):
        """Deep pipeline + many microbatches -> PP traffic dominates -> the
        record must prefer PP alignment (paper: dense models on H800)."""
        job = JobSpec(n_gpus=64 * 8, tp=8, pp=8, model=dense_model())
        rec = characterize(job, lambda: Cluster.uniform(8, 12))
        assert rec.j_pp >= rec.j_dp
        assert rec.unit == "pp"
        a, b = rec.affinity()
        assert a <= 0.5

    def test_alignment_beats_naive(self):
        job = JobSpec(n_gpus=64 * 8, tp=8, pp=8, model=dense_model())
        rec = characterize(job, lambda: Cluster.uniform(8, 12))
        assert rec.j_dp >= 0 and rec.j_pp >= 0
        assert rec.j_pp > 0  # alignment must beat random placement

    def test_sweep_feeds_db_and_lookup_uses_it(self):
        jobs = [
            JobSpec(n_gpus=32 * 8, tp=8, pp=4, model=dense_model(16)),
            JobSpec(n_gpus=64 * 8, tp=8, pp=8, model=moe_model()),
        ]
        recs = characterize_sweep(jobs, lambda: Cluster.uniform(8, 12))
        db = CharacterizationDB(records=recs)
        from repro.core import build_comm_matrix
        comm = build_comm_matrix(jobs[0])
        alpha, beta, unit = db.affinity_for(comm)
        assert abs(alpha + beta - 1.0) < 1e-9
        # nearest record should be the dense one we just characterized
        r1, r2 = comm.ratios()
        nearest = db.lookup(r1, r2)
        assert nearest.model_name == "dense-16L"
