"""Tests for the queue policy (Algorithm 1), JCT predictor, failures, and the
trace-driven simulator."""

import numpy as np
import pytest

from repro.core import (
    Cluster,
    FailureManager,
    JCTPredictor,
    Job,
    JobSpec,
    QueuePolicy,
    ScheduleRequest,
    TraceSimulator,
    build_comm_matrix,
    get_scheduler,
    max_spreads,
    poisson_trace,
    synthetic_trace,
    throughput_of_placement,
)
from repro.core.jct import GBMRegressor, RegressionTree


class TestGBM:
    def test_tree_fits_step_function(self):
        X = np.linspace(0, 1, 200).reshape(-1, 1)
        y = (X[:, 0] > 0.5).astype(float)
        tree = RegressionTree(max_depth=2, min_leaf=5).fit(X, y)
        pred = tree.predict(X)
        assert np.mean((pred - y) ** 2) < 0.01

    def test_gbm_beats_mean_baseline(self):
        rng = np.random.default_rng(0)
        X = rng.normal(size=(400, 4))
        y = 3 * X[:, 0] + np.sin(3 * X[:, 1]) + 0.1 * rng.normal(size=400)
        gbm = GBMRegressor(n_rounds=40).fit(X[:300], y[:300])
        pred = gbm.predict(X[300:])
        mse = np.mean((pred - y[300:]) ** 2)
        base = np.mean((y[300:] - y[:300].mean()) ** 2)
        assert mse < 0.3 * base

    def test_jct_predictor_rmse_close_to_paper(self):
        """Appendix G reports RMSE 1.61 buckets on a 90/10 split."""
        jobs, jct = synthetic_trace(1500, seed=1)
        n_train = int(0.9 * len(jobs))
        pred = JCTPredictor(n_bags=3, n_rounds=40).fit(jobs[:n_train], jct[:n_train])
        buckets = pred.predict_bucket(jobs[n_train:])
        true_b = JCTPredictor.to_bucket(jct[n_train:])
        rmse = float(np.sqrt(np.mean((buckets - true_b) ** 2)))
        base = float(np.sqrt(np.mean((true_b - true_b.mean()) ** 2)))
        assert rmse < base, "GBM must beat predicting the mean"
        assert rmse < 4.0, f"RMSE {rmse:.2f} too far from paper's 1.61"
        assert (pred.uncertainty(jobs[n_train:]) >= 0).all()


class TestQueuePolicy:
    def _policy(self, model7b, reserve=True, use_jct=True):
        cluster = Cluster.uniform(4, 16)
        policy = QueuePolicy(cluster, reserve=reserve, use_jct=use_jct)
        comm = build_comm_matrix(JobSpec(n_gpus=32 * 8, tp=4, pp=4, model=model7b))
        policy.plan_lpj(comm, arrival=1000.0, alpha=0.3)
        return cluster, policy

    def test_reservation_blocks_long_jobs(self, model7b):
        cluster, policy = self._policy(model7b)
        assert len(policy.reserved_nodes()) == 32
        # long job that cannot finish before LPJ arrival and needs reserve
        long_job = Job(job_id=1, n_nodes=40, arrival=0.0, duration=5000.0)
        policy.submit(long_job)
        assert policy.schedule_tick(now=0.0) == []  # delayed
        assert len(policy.queue) == 1

    def test_short_job_backfills_reserved_zone(self, model7b):
        cluster, policy = self._policy(model7b)
        short = Job(job_id=2, n_nodes=40, arrival=0.0, duration=100.0)
        policy.submit(short)
        started = policy.schedule_tick(now=0.0)
        assert started == [short] and short.in_reserved_zone

    def test_small_job_fits_outside(self, model7b):
        cluster, policy = self._policy(model7b)
        small = Job(job_id=3, n_nodes=8, arrival=0.0, duration=1e6)
        policy.submit(small)
        started = policy.schedule_tick(now=0.0)
        assert started == [small] and not small.in_reserved_zone

    def test_admit_lpj_preempts(self, model7b):
        cluster, policy = self._policy(model7b)
        squatter = Job(job_id=4, n_nodes=40, arrival=0.0, duration=100.0)
        policy.submit(squatter)
        policy.schedule_tick(now=0.0)
        nodes, preempted = policy.admit_lpj(now=1000.0)
        assert len(nodes) == 32
        assert squatter in preempted
        assert not cluster.is_free(nodes[0])

    def test_rates(self, model7b):
        cluster, policy = self._policy(model7b)
        assert policy.allocation_rate() == 0.0
        j = Job(job_id=5, n_nodes=40, arrival=0.0, duration=10.0)
        policy.submit(j)
        policy.schedule_tick(now=0.0)
        assert policy.allocation_rate() == pytest.approx(40 / 64)
        assert 0.0 <= policy.retention_rate() <= 1.0
        policy.complete(5)
        assert policy.allocation_rate() == 0.0


class TestSimulator:
    def test_trace_replay(self, model7b):
        cluster = Cluster.uniform(4, 16)
        policy = QueuePolicy(cluster)
        sim = TraceSimulator(policy, tick=60.0)
        jobs = poisson_trace(40, mean_interarrival=50.0, mean_duration=600.0,
                             max_nodes=16, seed=3)
        comm = build_comm_matrix(JobSpec(n_gpus=32 * 8, tp=4, pp=4, model=model7b))
        res = sim.run(jobs, t_end=4000.0, lpj_plan=(comm, 3000.0, 0.3, "pp"),
                      plan_at=500.0)
        assert len(res.lpj_nodes) == 32
        assert len(res.series) > 10
        rates = [p.allocation_rate for p in res.series]
        assert all(0.0 <= r <= 1.0 for r in rates)
        # retention decays after planning (Appendix H shape)
        post = [p.retention_rate for p in res.series if p.t > 2500.0]
        pre = [p.retention_rate for p in res.series if 500.0 < p.t < 1000.0]
        if pre and post:
            assert min(post) <= max(pre) + 1e-9

    def test_throughput_improves_with_lower_spread(self, model7b, cluster_iii):
        job = JobSpec(n_gpus=46 * 8 * 8, tp=8, pp=8, model=model7b)
        comm = build_comm_matrix(job)
        req = ScheduleRequest(comm=comm, cluster=cluster_iii, alpha=0.3, seed=0)
        good = get_scheduler("mip").schedule(req).placement
        bad = get_scheduler("random-fit").schedule(req).placement
        tg = throughput_of_placement(good)
        tb = throughput_of_placement(bad)
        assert tg["tokens_per_s"] > tb["tokens_per_s"]
        assert 0.0 < tg["comm_fraction"] < 1.0


class TestFailureManager:
    def test_backup_promotion_keeps_spread(self, model7b):
        cluster = Cluster.uniform(4, 20)
        comm = build_comm_matrix(JobSpec(n_gpus=32 * 8, tp=4, pp=4, model=model7b))
        res = get_scheduler("mip").schedule(
            ScheduleRequest(comm=comm, cluster=cluster, alpha=0.3))
        cluster.allocate(res.placement.node_ids())
        before = max_spreads(res.placement)
        fm = FailureManager(res.placement, cluster, backup_frac=0.1)
        assert fm.backup_count() >= 1
        pods_with_backup = {p for p, b in fm.backups.items() if b}
        victim = next(
            n for n in res.placement.node_ids()
            if cluster.nodes[n].minipod in pods_with_backup
        )
        ev = fm.on_failure(victim)
        assert ev.kind == "backup"
        assert (ev.dp_spread_after, ev.pp_spread_after) == before
        assert victim not in res.placement.node_ids()

    def test_cross_pod_fallback(self, model7b):
        cluster = Cluster.uniform(2, 8)
        comm = build_comm_matrix(JobSpec(n_gpus=12 * 8, tp=4, pp=2, model=model7b))
        res = get_scheduler("mip").schedule(
            ScheduleRequest(comm=comm, cluster=cluster, alpha=0.3))
        cluster.allocate(res.placement.node_ids())
        fm = FailureManager(res.placement, cluster, backup_frac=0.01)
        # exhaust backups then fail more nodes than local slack
        victims = res.placement.node_ids()
        kinds = set()
        for v in victims[:4]:
            try:
                kinds.add(fm.on_failure(v).kind)
            except Exception:
                break
        assert kinds <= {"backup", "local", "cross-pod"} and kinds

    def test_straggler_swap(self, model7b):
        cluster = Cluster.uniform(4, 20)
        comm = build_comm_matrix(JobSpec(n_gpus=32 * 8, tp=4, pp=4, model=model7b))
        res = get_scheduler("mip").schedule(
            ScheduleRequest(comm=comm, cluster=cluster, alpha=0.3))
        cluster.allocate(res.placement.node_ids())
        fm = FailureManager(res.placement, cluster, backup_frac=0.2)
        slow = res.placement.node_ids()[5]
        ev = fm.on_straggler(slow)
        assert ev is None or ev.kind == "backup"
