"""Block-allocator and paged-cache invariants (DESIGN.md §7.1): no
double-free, ownership enforced, and no leaked pages after a full trace."""

import numpy as np
import pytest

from repro.serve.kv_cache import (
    OutOfPages,
    PageAllocator,
    PagedCacheConfig,
    PagedKVCache,
)


class TestPageAllocator:
    def test_alloc_until_exhausted(self):
        a = PageAllocator(4)
        pages = [a.alloc(owner=0) for _ in range(4)]
        assert sorted(pages) == [0, 1, 2, 3]
        assert a.n_free == 0
        with pytest.raises(OutOfPages):
            a.alloc(owner=0)

    def test_free_recycles(self):
        a = PageAllocator(2)
        p = a.alloc(owner=1)
        a.free(p, owner=1)
        assert a.n_free == 2
        assert a.alloc(owner=2) == p  # LIFO reuse

    def test_double_free_raises(self):
        a = PageAllocator(2)
        p = a.alloc(owner=0)
        a.free(p, owner=0)
        with pytest.raises(ValueError, match="double free"):
            a.free(p, owner=0)

    def test_foreign_free_raises(self):
        a = PageAllocator(2)
        p = a.alloc(owner=0)
        with pytest.raises(ValueError, match="owned by lane 0"):
            a.free(p, owner=1)

    def test_pages_of_tracks_ownership(self):
        a = PageAllocator(4)
        mine = {a.alloc(owner=7) for _ in range(2)}
        a.alloc(owner=8)
        assert set(a.pages_of(7)) == mine

    def test_assert_all_free(self):
        a = PageAllocator(2)
        p = a.alloc(owner=0)
        with pytest.raises(AssertionError, match="leaked"):
            a.assert_all_free()
        a.free(p, owner=0)
        a.assert_all_free()

    def test_invalid_sizes_rejected(self):
        with pytest.raises(ValueError):
            PageAllocator(0)


class _FakeModel:
    """Stands in for DecoderLM: the cache only needs init_paged_cache."""

    def init_paged_cache(self, n_pages, page_size):
        return {"k": np.zeros((2, n_pages, page_size, 1, 4), np.float32),
                "v": np.zeros((2, n_pages, page_size, 1, 4), np.float32)}


def _cache(n_pages=8, page_size=4, max_batch=3, max_blocks=4):
    return PagedKVCache(_FakeModel(), PagedCacheConfig(
        n_pages=n_pages, page_size=page_size,
        max_batch=max_batch, max_blocks=max_blocks,
    ))


class TestPagedKVCache:
    def test_ensure_capacity_allocates_blocks_lazily(self):
        c = _cache()
        c.ensure_capacity(0, 1)
        assert c.n_blocks(0) == 1
        c.ensure_capacity(0, 4)   # still one page (page_size=4)
        assert c.n_blocks(0) == 1
        c.ensure_capacity(0, 5)   # crosses the boundary
        assert c.n_blocks(0) == 2
        assert c.allocator.n_free == 6

    def test_block_table_rows_are_disjoint(self):
        c = _cache()
        c.ensure_capacity(0, 8)
        c.ensure_capacity(1, 8)
        row0 = set(c.block_tables[0][c.block_tables[0] >= 0].tolist())
        row1 = set(c.block_tables[1][c.block_tables[1] >= 0].tolist())
        assert row0 and row1 and not (row0 & row1)

    def test_release_recycles_and_clears(self):
        c = _cache()
        c.ensure_capacity(2, 10)
        c.release(2)
        assert c.n_blocks(2) == 0
        assert (c.block_tables[2] == -1).all()
        c.allocator.assert_all_free()

    def test_max_context_enforced(self):
        c = _cache()
        with pytest.raises(ValueError, match="max context"):
            c.ensure_capacity(0, 17)  # 4 blocks * 4 tokens = 16 max

    def test_full_trace_leaves_no_leaks(self):
        """Random admit/grow/release trace: the allocator must end fully
        free and never hand a page to two lanes at once."""
        rng = np.random.default_rng(0)
        c = _cache(n_pages=12, page_size=4, max_batch=4, max_blocks=3)
        lengths = [0] * 4
        for _ in range(300):
            lane = int(rng.integers(0, 4))
            if lengths[lane] and rng.random() < 0.3:
                c.release(lane)
                lengths[lane] = 0
            else:
                want = min(lengths[lane] + int(rng.integers(1, 5)), 12)
                try:
                    c.ensure_capacity(lane, want)
                    lengths[lane] = want
                except OutOfPages:
                    c.release(lane)
                    lengths[lane] = 0
            live = c.block_tables[c.block_tables >= 0]
            assert len(live) == len(set(live.tolist()))  # no aliased pages
            assert c.allocator.n_allocated == len(live)
        for lane in range(4):
            if lengths[lane]:
                c.release(lane)
        c.allocator.assert_all_free()
        assert c.allocator.n_free == 12
