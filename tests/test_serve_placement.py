"""Replica placement tests (DESIGN.md §7.4): replicas land via the unified
scheduler registry, fall back on Infeasible, and roll back cleanly."""

import pytest

from repro.core import Cluster, FallbackChain, Infeasible, ModelSpec
from repro.serve import ReplicaSpec, place_replicas
from repro.serve.placement import serving_model_spec

MODEL = ModelSpec(
    name="serve-7b", hidden=4096, layers=32, vocab=50304, seq_len=4096,
    global_batch=32, micro_batch=1, d_ff=16384,
)
SPEC = ReplicaSpec(model=MODEL, tp=8, pp=2, n_gpus=16)  # 2 nodes/replica


class _AlwaysInfeasible:
    name = "always-infeasible"

    def schedule(self, request):
        raise Infeasible("synthetic failure")


class TestPlaceReplicas:
    def test_replicas_land_via_registry(self):
        cluster = Cluster.uniform(4, 4)
        rs = place_replicas(cluster, 3, SPEC, scheduler="mip")
        assert rs.n_replicas == 3
        ids = rs.node_ids()
        assert len(ids) == 6 == len(set(ids))          # disjoint, 2 nodes each
        assert cluster.n_free == cluster.n_nodes - 6   # held until release
        for p in rs.placements:
            assert p.result.method                      # produced by a policy
            assert p.result.pp_spread == 0              # replica fits one pod
        rs.release()
        assert cluster.n_free == cluster.n_nodes
        rs.release()                                    # idempotent

    def test_fallback_chain_engages_on_infeasible(self):
        cluster = Cluster.uniform(4, 4)
        chain = FallbackChain(_AlwaysInfeasible(), "topo-aware")
        rs = place_replicas(cluster, 2, SPEC, scheduler=chain)
        for p in rs.placements:
            assert p.method == "topo-aware"
            assert p.result.stats["fallbacks"][0][0] == "always-infeasible"
        rs.release()

    def test_infeasible_rolls_back_partial_placement(self):
        cluster = Cluster.uniform(2, 2)  # 4 nodes: 3rd replica cannot fit
        with pytest.raises(Infeasible):
            place_replicas(cluster, 3, SPEC, scheduler="mip,topo-aware")
        assert cluster.n_free == cluster.n_nodes  # nothing left allocated

    def test_bad_replica_count_rejected(self):
        with pytest.raises(ValueError):
            place_replicas(Cluster.uniform(2, 2), 0, SPEC)


class TestServingModelSpec:
    def test_maps_arch_config_fields(self):
        from repro.configs import get_config

        cfg = get_config("glm4-9b")
        spec = serving_model_spec(cfg, batch=16, seq_len=2048)
        assert spec.hidden == cfg.d_model
        assert spec.layers == cfg.n_layers
        assert spec.vocab == cfg.vocab
        assert spec.global_batch == 16 and spec.seq_len == 2048
        # usable end-to-end: the derived job builds a comm matrix
        replica = ReplicaSpec(model=spec, tp=8, pp=1, n_gpus=8)
        assert replica.comm().n_cells == 1
