"""Tests for logical-rank -> physical-GPU mapping (paper §6): permutation
validity, TP locality, and round-trips with ``node_rank_order``."""

import numpy as np
import pytest

from repro.core import (
    Cluster,
    JobSpec,
    ModelSpec,
    ScheduleRequest,
    build_comm_matrix,
    device_permutation,
    get_scheduler,
    logical_to_physical_gpus,
)
from repro.core.rank_assign import node_rank_order
from repro.core.topology import GPUS_PER_NODE

MODEL = ModelSpec(name="m", hidden=1024, layers=8, vocab=5000, seq_len=128,
                  global_batch=64, d_ff=4096)


def _placement(tp: int, pp: int, n_nodes: int, scheduler: str = "mip"):
    cluster = Cluster.uniform(4, max(2, n_nodes // 2))
    comm = build_comm_matrix(
        JobSpec(n_gpus=n_nodes * GPUS_PER_NODE, tp=tp, pp=pp, model=MODEL)
    )
    return get_scheduler(scheduler).schedule(
        ScheduleRequest(comm=comm, cluster=cluster, alpha=0.3)
    ).placement


class TestLogicalToPhysical:
    @pytest.mark.parametrize("tp", [1, 2, 4, 8])
    def test_bijective_over_gpus(self, tp):
        p = _placement(tp=tp, pp=2, n_nodes=8)
        phys = logical_to_physical_gpus(p, tp=tp)
        flat = phys.ravel()
        # every GPU of every placed node appears exactly once
        expected = sorted(
            g for n in p.node_ids()
            for g in range(n * GPUS_PER_NODE, (n + 1) * GPUS_PER_NODE)
        )
        assert sorted(int(g) for g in flat) == expected
        assert phys.shape == (p.comm.n_cols,
                              p.comm.n_rows * (GPUS_PER_NODE // tp), tp)

    @pytest.mark.parametrize("tp", [2, 4, 8])
    def test_tp_ranks_contiguous_within_a_node(self, tp):
        p = _placement(tp=tp, pp=2, n_nodes=8)
        phys = logical_to_physical_gpus(p, tp=tp)
        nodes = phys // GPUS_PER_NODE
        # all TP ranks of one (pp, dp) replica live on one node...
        assert (nodes == nodes[..., :1]).all()
        # ...on consecutive local GPU ids (NVLink locality, §2)
        local = phys % GPUS_PER_NODE
        assert (np.diff(local, axis=-1) == 1).all()

    def test_dp_replicas_of_a_cell_share_its_node(self):
        tp = 2
        p = _placement(tp=tp, pp=2, n_nodes=8)
        phys = logical_to_physical_gpus(p, tp=tp)
        reps = GPUS_PER_NODE // tp
        n_rows, n_cols = p.comm.shape
        for r in range(n_rows):
            for c in range(n_cols):
                hosted = phys[c, r * reps:(r + 1) * reps, :] // GPUS_PER_NODE
                assert (hosted == int(p.assignment[r, c])).all()

    @pytest.mark.parametrize("scheduler", ["mip", "topo-aware", "best-fit"])
    def test_round_trip_with_node_rank_order(self, scheduler):
        tp = 4
        p = _placement(tp=tp, pp=2, n_nodes=8, scheduler=scheduler)
        order = node_rank_order(p)
        # node_rank_order is the row-major ravel of the assignment
        assert (np.array(order).reshape(p.comm.shape) == p.assignment).all()
        # and logical_to_physical agrees with it cell by cell
        phys = logical_to_physical_gpus(p, tp=tp)
        reps = GPUS_PER_NODE // tp
        n_rows, n_cols = p.comm.shape
        recovered = [
            int(phys[c, r * reps, 0]) // GPUS_PER_NODE
            for r in range(n_rows) for c in range(n_cols)
        ]
        assert recovered == order

    def test_device_permutation_is_flat_ravel(self):
        tp = 4
        p = _placement(tp=tp, pp=2, n_nodes=8)
        perm = device_permutation(p, tp=tp)
        phys = logical_to_physical_gpus(p, tp=tp)
        assert perm == [int(g) for g in phys.ravel()]
        assert len(perm) == len(set(perm))
