"""Tests for Arnold's MILP scheduler (Eq. 4-10) and its greedy bounding."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need it; skip, not error
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    ALL_BASELINES,
    Cluster,
    Infeasible,
    JobSpec,
    build_comm_matrix,
    max_spreads,
    schedule_mip,
    weighted_spread,
)
from repro.core.mip import (
    _counts_objective,
    _greedy_candidates,
    _objective_lower_bound,
    _solve_counts,
)


class TestSolveCounts:
    def test_feasible_counts_respect_capacity_and_allocation(self):
        free = np.array([4.0, 4.0, 4.0])
        counts, obj, _, _ = _solve_counts(2, 5, free, 0.3, 0.7, True, 10.0)
        assert counts.shape == (5, 3)
        assert (counts.sum(axis=1) == 2).all()          # Eq. 7 allocation
        assert (counts.sum(axis=0) <= free).all()       # Eq. 6 capacity
        assert obj >= _objective_lower_bound(2, 5, free, 0.3, 0.7) - 1e-9

    def test_infeasible_raises(self):
        with pytest.raises(Infeasible):
            _solve_counts(4, 10, np.array([3.0, 3.0]), 0.5, 0.5, True, 10.0)

    def test_alpha_zero_minimizes_unit_spread(self):
        # beta=1: every group should land in exactly one pod when possible.
        free = np.array([8.0, 8.0, 8.0, 8.0])
        counts, obj, _, _ = _solve_counts(4, 8, free, 0.0, 1.0, True, 10.0)
        assert max((row > 0).sum() for row in counts) == 1

    def test_alpha_one_is_pure_binpacking(self):
        # alpha=1 reduces to minimizing pods used (paper §7.1 observation).
        free = np.array([16.0, 8.0, 8.0])
        counts, obj, _, _ = _solve_counts(4, 4, free, 1.0, 0.0, True, 10.0)
        assert (counts.sum(axis=0) > 0).sum() == 1  # all 16 nodes fit pod 0

    def test_greedy_skips_solver_when_bound_met(self):
        free = np.array([64.0, 64.0])
        counts, obj, dt, method = _solve_counts(8, 8, free, 0.3, 0.7, True, 10.0)
        assert method == "greedy-proven-optimal"
        assert dt < 0.5

    @given(
        group_size=st.sampled_from([1, 2, 4, 8]),
        m=st.integers(1, 12),
        pods=st.lists(st.integers(0, 40), min_size=2, max_size=8),
        alpha=st.sampled_from([0.0, 0.3, 0.5, 1.0]),
    )
    @settings(max_examples=40, deadline=None)
    def test_property_feasibility(self, group_size, m, pods, alpha):
        free = np.array(pods, dtype=float)
        if free.sum() < group_size * m:
            with pytest.raises(Infeasible):
                _solve_counts(group_size, m, free, alpha, 1 - alpha, True, 5.0)
            return
        counts, obj, _, _ = _solve_counts(group_size, m, free, alpha, 1 - alpha, True, 5.0)
        assert (counts.sum(axis=1) == group_size).all()
        assert (counts.sum(axis=0) <= free + 1e-9).all()
        assert obj >= _objective_lower_bound(group_size, m, free, alpha, 1 - alpha) - 1e-9


class TestGreedyBound:
    @given(
        group_size=st.sampled_from([2, 4, 8]),
        m=st.integers(1, 10),
        pods=st.lists(st.integers(1, 30), min_size=2, max_size=6),
    )
    @settings(max_examples=60, deadline=None)
    def test_lower_bound_is_a_bound(self, group_size, m, pods):
        free = np.array(pods, dtype=float)
        if free.sum() < group_size * m:
            return
        lb = _objective_lower_bound(group_size, m, free, 0.3, 0.7)
        cand, obj = _greedy_candidates(group_size, m, free, 0.3, 0.7)
        if cand is not None:
            assert obj >= lb - 1e-9
            assert (cand.sum(axis=1) == group_size).all()
            assert (cand.sum(axis=0) <= free).all()


class TestScheduleMip:
    def test_end_to_end_small(self, small_comm, cluster_i):
        res = schedule_mip(small_comm, cluster_i, alpha=0.3)
        p = res.placement
        assert sorted(p.node_ids()) == sorted(set(p.node_ids()))
        assert all(cluster_i.is_free(n) for n in p.node_ids())
        assert res.max_unit_spread >= 1

    def test_beats_or_ties_all_baselines_setting_iii(self, model7b):
        cluster = Cluster.paper_setting("iii")
        job = JobSpec(n_gpus=46 * 8 * 8, tp=8, pp=8, model=model7b)
        comm = build_comm_matrix(job)
        for alpha in (0.0, 0.3, 0.5):
            res = schedule_mip(comm, cluster, alpha=alpha)
            ours = weighted_spread(res.placement, alpha)
            for name, fn in ALL_BASELINES.items():
                theirs = weighted_spread(fn(comm, cluster), alpha)
                assert ours <= theirs + 1e-9, (alpha, name, ours, theirs)

    def test_fragmented_cluster(self, model7b):
        """Partially-occupied cluster: the greedy bound usually cannot prove
        optimality here, exercising the real MILP path."""
        cluster = Cluster.uniform(4, 24)
        rng = np.random.default_rng(0)
        busy = rng.choice(cluster.n_nodes, size=40, replace=False)
        cluster.allocate([int(b) for b in busy])
        job = JobSpec(n_gpus=24 * 8, tp=4, pp=4, model=model7b)  # 24 nodes
        comm = build_comm_matrix(job)
        res = schedule_mip(comm, cluster, alpha=0.3, time_limit=10.0)
        assert all(cluster.is_free(n) for n in res.placement.node_ids())

    def test_rank_contiguity_within_rows(self, small_comm, cluster_i):
        """§5.2 rank re-indexing: within each PP group (row), the stages
        hosted by one minipod occupy a contiguous run of pipeline ranks, so
        send-recv crosses a pod boundary at most (spread-1) times."""
        res = schedule_mip(small_comm, cluster_i, alpha=0.3)
        pods = res.placement.minipod_of()
        for r in range(pods.shape[0]):
            row = list(pods[r, :])
            # no pod appears, disappears, then reappears along the chain
            seen, prev = set(), None
            for p in row:
                if p != prev:
                    assert p not in seen, f"row {r}: pod {p} re-appears in {row}"
                    seen.add(p)
                prev = p

    def test_unit_dp(self, small_comm, cluster_i):
        res = schedule_mip(small_comm, cluster_i, alpha=0.3, unit="dp")
        assert res.placement.assignment.shape == small_comm.shape

    def test_bad_unit(self, small_comm, cluster_i):
        with pytest.raises(ValueError):
            schedule_mip(small_comm, cluster_i, alpha=0.3, unit="tp")
