"""Property tests for the grouped MoE dispatch (GSPMD-canonical form)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need it; skip, not error
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.models import layers as L


def make_moe(key, d, E, ff):
    return L.init_moe(key, d, E, ff, jnp.float32)


class TestGroupedDispatch:
    def test_matches_ungrouped_when_capacity_ample(self):
        """With capacity >> tokens/expert, grouping must not change results:
        every token reaches its experts regardless of group boundaries."""
        key = jax.random.PRNGKey(0)
        d, E, ff = 16, 4, 32
        p = make_moe(key, d, E, ff)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, d)) * 0.5
        outs = []
        for gs in (8, 16, 32):
            outs.append(
                L.moe_fwd(p, x, top_k=2, capacity_factor=8.0, group_size=gs)
            )
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(outs[1]),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(outs[2]),
                                   rtol=1e-5, atol=1e-5)

    def test_single_expert_equals_dense_mlp(self):
        """E=1, top_k=1: MoE must reduce to the (SwiGLU) expert applied to
        every token with gate weight 1."""
        key = jax.random.PRNGKey(0)
        d, ff = 12, 24
        p = make_moe(key, d, 1, ff)
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, d)) * 0.5
        out = L.moe_fwd(p, x, top_k=1, capacity_factor=4.0, group_size=8)
        # manual dense expert
        g = x @ p["w_gate"][0]
        h = x @ p["w_in"][0]
        expect = (jax.nn.silu(g) * h) @ p["w_out"][0]
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=1e-4, atol=1e-5)

    def test_capacity_drops_tokens(self):
        """With capacity_factor ~0, (almost) all tokens are dropped and the
        output collapses to ~zero."""
        key = jax.random.PRNGKey(0)
        p = make_moe(key, 8, 4, 16)
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 8))
        out_full = L.moe_fwd(p, x, top_k=2, capacity_factor=8.0, group_size=64)
        out_tiny = L.moe_fwd(p, x, top_k=2, capacity_factor=0.01, group_size=64)
        assert float(jnp.abs(out_tiny).mean()) < float(jnp.abs(out_full).mean())

    @given(
        tokens=st.sampled_from([8, 16, 32]),
        E=st.sampled_from([2, 4, 8]),
        top_k=st.sampled_from([1, 2]),
    )
    @settings(max_examples=15, deadline=None)
    def test_combine_weights_bounded(self, tokens, E, top_k):
        """Output norm is bounded by the max expert output norm: combine
        weights per token sum to <= 1 (softmax renormalized over kept)."""
        key = jax.random.PRNGKey(tokens * 31 + E)
        p = make_moe(key, 8, E, 16)
        x = jax.random.normal(jax.random.PRNGKey(1), (1, tokens, 8))
        out = L.moe_fwd(p, x, top_k=top_k, capacity_factor=8.0, group_size=tokens)
        assert bool(jnp.all(jnp.isfinite(out)))

    def test_aux_loss_balanced_router_is_one(self):
        """A perfectly uniform router gives aux ~ 1 (Switch normalization)."""
        d, E = 8, 4
        key = jax.random.PRNGKey(0)
        p = make_moe(key, d, E, 16)
        p = dict(p)
        p["router"] = jnp.zeros((d, E))  # uniform probs
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, d))
        _, aux = L.moe_fwd(p, x, top_k=1, capacity_factor=4.0, group_size=32,
                           return_aux=True)
        assert 0.9 < float(aux) < 1.1

    def test_grad_flows_to_experts_and_router(self):
        key = jax.random.PRNGKey(0)
        p = make_moe(key, 8, 4, 16)
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 8))

        def loss(p):
            return jnp.sum(L.moe_fwd(p, x, top_k=2, capacity_factor=2.0,
                                     group_size=16) ** 2)

        g = jax.grad(loss)(p)
        for name in ("router", "w_gate", "w_in", "w_out"):
            assert float(jnp.abs(g[name]).max()) > 0.0, name
