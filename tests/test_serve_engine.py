"""Continuous-batching engine tests (DESIGN.md §7.2): fast smoke on the
default tier, batched-vs-sequential token equivalence, mid-flight admission
under lane pressure, EOS early stop, and page recycling."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import ModelOptions, build_model
from repro.serve import EngineConfig, GenerationRequest, ServeEngine

CFG = EngineConfig(max_batch=4, page_size=8, n_pages=32, max_blocks=4)


@pytest.fixture(scope="module")
def tiny_model():
    cfg = get_config("glm4-9b").reduced()
    model = build_model(cfg, ModelOptions(compute_dtype="float32", remat=False))
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _requests(cfg, n, seed=0, max_new=(4, 8), prompt_len=(3, 10)):
    rng = np.random.default_rng(seed)
    return [
        GenerationRequest(
            request_id=i,
            prompt=tuple(int(t) for t in rng.integers(
                0, cfg.vocab, int(rng.integers(*prompt_len)))),
            max_new_tokens=int(rng.integers(*max_new)),
        )
        for i in range(n)
    ]


def test_engine_smoke(tiny_model):
    """Fast default-tier smoke: <= 8 requests, tiny config."""
    cfg, model, params = tiny_model
    engine = ServeEngine(model, params, CFG)
    requests = _requests(cfg, 6)
    results, stats = engine.run(requests)

    assert len(results) == 6
    for res, req in zip(results, requests):
        assert res.request_id == req.request_id
        assert len(res.tokens) == req.max_new_tokens
        assert all(0 <= t < cfg.vocab for t in res.tokens)
        assert len(res.token_times_s) == len(res.tokens)
        assert res.token_times_s == sorted(res.token_times_s)
        assert res.arrival_s <= res.admitted_s <= res.finished_s
    # exact token accounting: everything counted was generated in-window
    assert stats.tokens_generated == sum(r.max_new_tokens for r in requests)
    assert stats.elapsed_s > 0 and stats.tokens_per_s > 0
    # pages recycled: allocator ends fully free
    engine.cache.allocator.assert_all_free()
    assert engine.cache.allocator.n_free == CFG.n_pages


def _sequential_reference(model, params, prompt, n_tokens):
    """Greedy decode one sequence at a time via the dense cache path."""
    step = jax.jit(model.decode_step, donate_argnums=(1,))
    cache = model.init_cache(1, 32)
    logits = None
    for t in prompt:
        logits, cache = step(params, cache, jnp.full((1, 1), t, jnp.int32))
    tokens = [int(jnp.argmax(logits[0, -1]))]
    while len(tokens) < n_tokens:
        logits, cache = step(
            params, cache, jnp.full((1, 1), tokens[-1], jnp.int32))
        tokens.append(int(jnp.argmax(logits[0, -1])))
    return tokens


def test_continuous_batching_matches_sequential_decode(tiny_model):
    """The paged batched engine must produce exactly the tokens the dense
    one-at-a-time decode produces -- per-lane math is batch-invariant."""
    cfg, model, params = tiny_model
    engine = ServeEngine(model, params, EngineConfig(
        max_batch=3, page_size=8, n_pages=24, max_blocks=4))
    requests = _requests(cfg, 4, seed=7, max_new=(3, 7))
    results, _ = engine.run(requests)
    for res in results:
        ref = _sequential_reference(
            model, params, list(res.prompt), len(res.tokens))
        assert res.tokens == ref, f"request {res.request_id} diverged"


def test_mid_flight_admission_under_lane_pressure(tiny_model):
    """More requests than lanes: later requests join as earlier ones evict,
    never exceeding max_batch, and all pages still recycle."""
    cfg, model, params = tiny_model
    config = EngineConfig(max_batch=2, page_size=8, n_pages=16, max_blocks=4)
    engine = ServeEngine(model, params, config)
    results, stats = engine.run(_requests(cfg, 5, seed=3))
    assert len(results) == 5
    assert max(stats.occupancy) <= 2
    assert stats.peak_pages_in_use <= config.n_pages
    engine.cache.allocator.assert_all_free()


def test_oversized_request_rejected(tiny_model):
    cfg, model, params = tiny_model
    engine = ServeEngine(model, params, CFG)  # max context 32
    with pytest.raises(ValueError, match="max context"):
        engine.submit(GenerationRequest(
            request_id=0, prompt=(1,) * 20, max_new_tokens=20))
    # fits the per-lane context but not the whole pool: reject at submit
    # rather than hang in admission forever
    small_pool = ServeEngine(model, params, EngineConfig(
        max_batch=2, page_size=8, n_pages=3, max_blocks=4))
    with pytest.raises(ValueError, match="never be admitted"):
        small_pool.submit(GenerationRequest(
            request_id=0, prompt=(1,) * 16, max_new_tokens=16))


def test_eos_stops_early(tiny_model):
    cfg, model, params = tiny_model
    probe = ServeEngine(model, params, CFG)
    [free_run], _ = probe.run(_requests(cfg, 1, seed=1, max_new=(6, 7)))
    assert len(free_run.tokens) >= 3

    eos = free_run.tokens[2]  # force a stop at the third generated token
    engine = ServeEngine(model, params, CFG)
    req = GenerationRequest(
        request_id=0, prompt=free_run.prompt,
        max_new_tokens=len(free_run.tokens), eos_id=eos)
    [res], _ = engine.run([req])
    assert res.finish_reason == "eos"
    assert res.tokens == free_run.tokens[:3]
    engine.cache.allocator.assert_all_free()


def test_prefill_only_request(tiny_model):
    """max_new_tokens=1 finishes at prefill without any decode tick."""
    cfg, model, params = tiny_model
    engine = ServeEngine(model, params, CFG)
    [res], stats = engine.run([GenerationRequest(
        request_id=0, prompt=(5, 6, 7), max_new_tokens=1)])
    assert len(res.tokens) == 1
    assert stats.prefills == 1 and stats.decode_steps == 0
    engine.cache.allocator.assert_all_free()
