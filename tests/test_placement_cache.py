"""Tests for Cluster.free_signature and the PlacementCache (DESIGN.md §8.3)."""

import numpy as np
import pytest

from repro.core import Cluster, PlacementCache


class TestFreeSignature:
    def test_full_cluster(self):
        cluster = Cluster.uniform(3, 8)
        assert cluster.free_signature() == (8, 8, 8)

    def test_quantizes_down(self):
        cluster = Cluster.uniform(3, 8)
        cluster.allocate([0, 1, 2])  # minipod 0: 5 free
        assert cluster.free_signature(1) == (5, 8, 8)
        assert cluster.free_signature(4) == (4, 8, 8)
        assert cluster.free_signature(8) == (0, 8, 8)

    def test_hashable_and_restorable(self):
        cluster = Cluster.uniform(2, 4)
        sig = cluster.free_signature(2)
        assert hash(sig) == hash((4, 4))
        cluster.allocate([0])
        assert cluster.free_signature(2) == (2, 4)
        cluster.release([0])
        assert cluster.free_signature(2) == sig

    def test_small_drift_invisible_under_quantum(self):
        a = Cluster.uniform(4, 20)
        b = Cluster.uniform(4, 20)
        b.allocate([0, 1, 2])  # pod 0: 17 free, same 16-bucket as 20
        assert a.free_signature(8) == b.free_signature(8)

    def test_bad_quantum_rejected(self):
        with pytest.raises(ValueError, match="quantum"):
            Cluster.uniform(2, 4).free_signature(0)


class TestPlacementCache:
    def _key(self, cache, cluster, small_comm, **kw):
        defaults = dict(unit="pp", alpha=0.3, beta=0.7)
        defaults.update(kw)
        return cache.key(small_comm, cluster, **defaults)

    def test_miss_then_hit_counters(self, small_comm):
        cache = PlacementCache(quantum=4)
        cluster = Cluster.uniform(4, 8)
        free = np.array(cluster.free_capacities(), dtype=float)
        key = self._key(cache, cluster, small_comm)
        assert cache.lookup(key, free) is None
        counts = np.zeros((6, 4), dtype=int)
        counts[:, 0] = 1
        counts[:, 1] = 1
        cache.store(key, counts)
        got = cache.lookup(key, free)
        assert (got == counts).all()
        assert (cache.stats.hits, cache.stats.misses) == (1, 1)
        assert cache.stats.hit_rate() == pytest.approx(0.5)
        assert cache.stats.as_dict()["hit_rate"] == pytest.approx(0.5)

    def test_hit_returns_copy(self, small_comm):
        cache = PlacementCache()
        cluster = Cluster.uniform(4, 8)
        free = np.array(cluster.free_capacities(), dtype=float)
        key = self._key(cache, cluster, small_comm)
        cache.store(key, np.ones((6, 4), dtype=int))
        got = cache.lookup(key, free)
        got[0, 0] = 99
        assert cache.lookup(key, free)[0, 0] == 1

    def test_stale_entry_revalidated_as_miss(self, small_comm):
        """Quantized signature unchanged but a pod lost a node the cached
        solution needs -> must miss, never produce an infeasible placement."""
        cache = PlacementCache(quantum=8)
        cluster = Cluster.uniform(4, 10)
        key = self._key(cache, cluster, small_comm)
        counts = np.zeros((6, 4), dtype=int)
        counts[:, 0] = 1
        counts[0, 0] = 5  # pod 0 carries all 10 of its nodes
        counts[:, 1] = 1
        cache.store(key, counts)
        cluster.allocate([0])  # pod 0: 9 free, still in the 8-bucket
        stale_key = self._key(cache, cluster, small_comm)
        assert stale_key == key  # the quantized key cannot tell
        free = np.array(cluster.free_capacities(), dtype=float)
        assert cache.lookup(stale_key, free) is None
        assert cache.stats.misses == 1

    def test_key_separates_problems(self, small_comm, small_job):
        from repro.core import JobSpec, build_comm_matrix

        cache = PlacementCache()
        cluster = Cluster.uniform(4, 8)
        base = self._key(cache, cluster, small_comm)
        assert self._key(cache, cluster, small_comm, alpha=0.5, beta=0.5) != base
        assert self._key(cache, cluster, small_comm, unit="dp") != base
        other = build_comm_matrix(
            JobSpec(n_gpus=64, tp=8, pp=8, model=small_job.model))
        assert self._key(cache, cluster, other) != base
        assert cache.key(small_comm, cluster, "pp", 0.3, 0.7,
                         extra=("ppb", 4)) != base
        assert self._key(cache, Cluster.uniform(5, 8), small_comm) != base

    def test_lru_eviction(self, small_comm):
        cache = PlacementCache(maxsize=2)
        cluster = Cluster.uniform(4, 8)
        free = np.array(cluster.free_capacities(), dtype=float)
        keys = [self._key(cache, cluster, small_comm, alpha=a)
                for a in (0.1, 0.2, 0.3)]
        for k in keys:
            cache.store(k, np.zeros((6, 4), dtype=int))
        assert len(cache) == 2
        assert cache.lookup(keys[0], free) is None  # oldest evicted
        assert cache.lookup(keys[2], free) is not None

    def test_clear_resets_everything(self, small_comm):
        cache = PlacementCache()
        cluster = Cluster.uniform(4, 8)
        key = self._key(cache, cluster, small_comm)
        cache.store(key, np.zeros((6, 4), dtype=int))
        cache.lookup(key, np.array(cluster.free_capacities(), dtype=float))
        cache.clear()
        assert len(cache) == 0
        assert cache.stats.lookups == 0

    def test_bad_quantum_rejected(self):
        with pytest.raises(ValueError, match="quantum"):
            PlacementCache(quantum=0)
