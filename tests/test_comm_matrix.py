"""Tests for the workload representation (Eq. 1) and Appendix C volumes."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need it; skip, not error
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core import (
    JobSpec,
    ModelSpec,
    build_comm_matrix,
    dp_volume_bytes,
    ep_volume_bytes,
    pp_volume_bytes,
)

GB = 1 << 30
MB = 1 << 20


class TestEq1:
    def test_paper_example(self, model7b):
        """Figure 12: 96 GPUs, DP=6, PP=2 -> 6x2 matrix of 12 nodes."""
        job = JobSpec(n_gpus=96, tp=4, pp=2, model=model7b)
        comm = build_comm_matrix(job)
        assert job.dp == 12  # 96/4/2
        assert comm.shape == (6, 2)  # DP/(8/TP) = 12/2 = 6 rows, PP=2 cols
        assert comm.n_cells == job.n_nodes == 12

    @given(
        tp=st.sampled_from([1, 2, 4, 8]),
        pp=st.sampled_from([1, 2, 4, 8]),
        rows=st.integers(1, 16),
    )
    @settings(max_examples=60, deadline=None,
              suppress_health_check=[HealthCheck.function_scoped_fixture])
    def test_matrix_accounts_every_node(self, model7b, tp, pp, rows):
        dp = rows * (8 // tp)
        job = JobSpec(n_gpus=dp * tp * pp, tp=tp, pp=pp, model=model7b)
        comm = build_comm_matrix(job)
        assert comm.n_rows * comm.n_cols == job.n_nodes
        assert comm.n_cols == pp

    def test_rejects_intra_node_tp_violation(self, model7b):
        with pytest.raises(ValueError):
            JobSpec(n_gpus=96, tp=16, pp=2, model=model7b)  # TP > node size

    def test_rejects_non_divisible(self, model7b):
        with pytest.raises(ValueError):
            JobSpec(n_gpus=100, tp=4, pp=2, model=model7b)


class TestAppendixC:
    def test_paper_sanity_numbers(self, model7b):
        """§4: 'substituting the parameters with a 7B GPT-based model ... the
        data volumes of the DP and PP groups are 2 GB and 30 MB'."""
        job = JobSpec(n_gpus=64, tp=4, pp=8, model=model7b)
        v_d = dp_volume_bytes(job)
        v_p = pp_volume_bytes(job)
        assert 1.5 * GB < v_d < 2.5 * GB, f"DP volume {v_d / GB:.2f} GB"
        assert 25 * MB < v_p < 40 * MB, f"PP volume {v_p / MB:.1f} MB"

    def test_dp_volume_scales_inverse_pp(self, model7b):
        j2 = JobSpec(n_gpus=64, tp=4, pp=2, model=model7b)
        j8 = JobSpec(n_gpus=256, tp=4, pp=8, model=model7b)
        # layer term dominates; embedding term is PP-independent
        assert dp_volume_bytes(j2) > 2.5 * dp_volume_bytes(j8)

    def test_pp_volume_independent_of_pp_degree(self, model7b):
        j2 = JobSpec(n_gpus=64, tp=4, pp=2, model=model7b)
        j8 = JobSpec(n_gpus=256, tp=4, pp=8, model=model7b)
        assert pp_volume_bytes(j2) == pp_volume_bytes(j8)

    def test_moe_ep_volume(self):
        moe = ModelSpec(
            name="moe", hidden=4096, layers=24, vocab=50304, seq_len=2048,
            global_batch=512, micro_batch=1, n_experts=16, top_k=4, d_expert=8192,
        )
        job = JobSpec(n_gpus=128, tp=4, pp=2, model=moe)
        v_e = ep_volume_bytes(job)
        # 2 * top_k * s * h * bytes = 2*4*2048*4096*2
        assert v_e == 2 * 4 * 2048 * 4096 * 2
        dense = ModelSpec(
            name="d", hidden=4096, layers=24, vocab=50304, seq_len=2048,
            global_batch=512, d_ff=16384,
        )
        assert ep_volume_bytes(JobSpec(n_gpus=128, tp=4, pp=2, model=dense)) == 0

    def test_ratios_positive(self, small_comm):
        r1, r2 = small_comm.ratios()
        assert r1 > 0 and r2 > 0
        # dense LPJ: DP volume >> PP volume per step
        assert r2 > 1
