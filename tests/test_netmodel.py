"""Tests for the calibrated BusBw / step-time model (paper §4 encoding)."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need it; skip, not error
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import JobSpec, ModelSpec, build_comm_matrix, simulate_step_time
from repro.core.netmodel import GB, MB, NetModel


@pytest.fixture
def net():
    return NetModel()


class TestBusBw:
    def test_collective_saturation_curve(self, net):
        """Fig. 4a: collectives need ~256MB to approach peak."""
        b64 = net.collective_busbw(64 * MB, 1)
        b256 = net.collective_busbw(256 * MB, 1)
        b2g = net.collective_busbw(2 * GB, 1)
        assert b64 < b256 < b2g
        assert b256 / net.cfg.peak_busbw > 0.8
        assert net.collective_busbw(1 * MB, 1) / net.cfg.peak_busbw < 0.05

    def test_p2p_saturates_small(self, net):
        """Fig. 4a: ~2MB saturates send-recv."""
        assert net.p2p_busbw(2 * MB, 1) / net.cfg.peak_busbw > 0.85

    def test_degradation_caps(self, net):
        """Fig. 4b/c: -17% collective, -70% P2P at max spread; monotone."""
        c = [net.collective_busbw(2 * GB, s) for s in (1, 2, 3, 5)]
        p = [net.p2p_busbw(32 * MB, s) for s in (1, 2, 3, 5)]
        assert c[0] > c[1] > c[2] == c[3]
        assert p[0] > p[1] > p[2] == p[3]
        assert 1 - c[2] / c[0] == pytest.approx(0.17)
        assert 1 - p[2] / p[0] == pytest.approx(0.70)

    @given(spread=st.integers(1, 8), size_mb=st.floats(0.1, 4096))
    @settings(max_examples=50, deadline=None)
    def test_property_bandwidth_positive_and_bounded(self, spread, size_mb):
        net = NetModel()
        for fn in (net.collective_busbw, net.p2p_busbw):
            bw = fn(size_mb * MB, spread)
            assert 0 < bw <= net.cfg.peak_busbw

    def test_interference_bounds(self, net):
        rng = np.random.default_rng(0)
        for s in (1, 3, 6):
            x = net.interference(s, rng)
            assert 1.0 <= x <= 1.0 + net.cfg.interference_max + 1e-9


class TestStepTime:
    def _comm(self, pp=8, moe=False):
        if moe:
            m = ModelSpec(name="moe", hidden=4096, layers=32, vocab=50304,
                          seq_len=2048, global_batch=512, micro_batch=1,
                          n_experts=16, top_k=4, d_expert=8192)
        else:
            m = ModelSpec(name="d", hidden=4096, layers=32, vocab=50304,
                          seq_len=2048, global_batch=512, micro_batch=1,
                          d_ff=16384)
        return build_comm_matrix(JobSpec(n_gpus=64 * 8, tp=8, pp=pp, model=m))

    def test_spread_slows_step(self):
        comm = self._comm()
        t1 = simulate_step_time(comm, 1, 1).total
        t3 = simulate_step_time(comm, 3, 3).total
        assert t3 > t1

    def test_comm_fraction_in_paper_band(self):
        """Fig. 1a: 30-50% of production step time is communication."""
        comm = self._comm()
        bd = simulate_step_time(comm, 2, 2)
        assert 0.05 < bd.comm_fraction() < 0.6

    def test_pp1_has_no_pp_time(self):
        comm = self._comm(pp=1)
        bd = simulate_step_time(comm, 2, 1)
        assert bd.pp_exposed == 0.0

    def test_moe_has_ep_time(self):
        bd = simulate_step_time(self._comm(moe=True), 1, 1)
        assert bd.ep_exposed > 0.0
        assert simulate_step_time(self._comm(moe=False), 1, 1).ep_exposed == 0.0
