"""Tests for the pluggable fabric subsystem (repro.topo, DESIGN.md §9).

Covers the registry, per-fabric hop-distance metric properties
(identity / symmetry / diameter bound, randomized over domain pairs),
CLOS parity of the fabric-generic spread and scheduling paths against the
pre-fabric behaviour, and the per-fabric network-model dispatch.
"""

import numpy as np
import pytest

from repro.core import (
    Cluster,
    JobSpec,
    ModelSpec,
    NetModel,
    Placement,
    ScheduleRequest,
    build_comm_matrix,
    get_scheduler,
    max_spreads,
    weighted_spread,
)
from repro.core.netmodel import (
    ClosNetModel,
    DragonflyNetModel,
    FabricNetModel,
    RailOnlyNetModel,
    TorusNetModel,
    fabric_net_model,
    simulate_step_time,
)
from repro.core.spread import distance_onehot, group_spread, max_hop_diameters
from repro.topo import (
    BaseFabric,
    ClosFabric,
    DragonflyFabric,
    RailOnlyFabric,
    TorusFabric,
    comparable_fabric,
    fabric_class,
    get_fabric,
    list_fabrics,
    register_fabric,
)


def sample_fabrics():
    """One small instance per family (non-uniform where the family allows)."""
    return [
        ClosFabric([6, 5, 7]),
        RailOnlyFabric([4, 4, 4, 4], rails=4),
        TorusFabric((2, 3), nodes_per_domain=4),
        TorusFabric((2, 2, 3), nodes_per_domain=2),
        DragonflyFabric(n_groups=3, routers_per_group=2, nodes_per_router=4),
    ]


# ---------------------------------------------------------------- registry
class TestRegistry:
    def test_required_fabrics_registered(self):
        assert {"clos", "rail-only", "torus", "dragonfly"} <= set(list_fabrics())

    def test_aliases_resolve(self):
        assert fabric_class("rail") is RailOnlyFabric
        assert fabric_class("minipod") is ClosFabric
        assert fabric_class("fat-tree") is ClosFabric

    def test_get_fabric_instantiates(self):
        fab = get_fabric("clos", [4, 4])
        assert isinstance(fab, ClosFabric) and fab.n_nodes == 8

    def test_unknown_fabric_raises(self):
        with pytest.raises(KeyError):
            fabric_class("hypercube")

    def test_duplicate_registration_requires_overwrite(self):
        with pytest.raises(ValueError):
            register_fabric("clos", ClosFabric)


# ----------------------------------------------------- hop-distance metric
class TestDistanceProperties:
    @pytest.mark.parametrize("fab", sample_fabrics(), ids=lambda f: f.kind)
    def test_identity_symmetry_bounds(self, fab):
        """d(a,a)=0, d(a,b)=d(b,a), 0 <= d <= diameter -- randomized pairs."""
        rng = np.random.default_rng(0)
        diam = fab.diameter()
        for _ in range(200):
            a, b = rng.integers(0, fab.n_domains, size=2)
            d = fab.domain_distance(int(a), int(b))
            assert d == fab.domain_distance(int(b), int(a))
            assert 0 <= d <= diam
            if a == b:
                assert d == 0
        assert fab.domain_distance(0, 0) == 0

    @pytest.mark.parametrize("fab", sample_fabrics(), ids=lambda f: f.kind)
    def test_diameter_attained(self, fab):
        dists = [
            fab.domain_distance(a, b)
            for a in range(fab.n_domains)
            for b in range(fab.n_domains)
        ]
        assert max(dists) == fab.diameter()

    @pytest.mark.parametrize("fab", sample_fabrics(), ids=lambda f: f.kind)
    def test_distance_at_spread_matches_bruteforce(self, fab):
        """distance_at_spread(q) is the tightest q-domain ball's diameter."""
        k = fab.n_domains
        mat = np.array(
            [[fab.domain_distance(a, b) for b in range(k)] for a in range(k)]
        )
        for q in range(2, k + 1):
            # brute force: for every center, the q nearest domains' diameter
            best = None
            for c in range(k):
                near = np.argsort(mat[c], kind="stable")[:q]
                diam = int(mat[np.ix_(near, near)].max())
                best = diam if best is None else min(best, diam)
            assert fab.distance_at_spread(q) == best, (fab.kind, q)
        assert fab.distance_at_spread(1) == 0

    @pytest.mark.parametrize("fab", sample_fabrics(), ids=lambda f: f.kind)
    def test_distance_at_spread_monotone(self, fab):
        vals = [fab.distance_at_spread(q) for q in range(1, fab.n_domains + 1)]
        assert vals[0] == 0
        assert all(a <= b for a, b in zip(vals, vals[1:]))
        assert vals[-1] <= fab.diameter()

    def test_torus_wraparound_known_values(self):
        fab = TorusFabric((4, 4), nodes_per_domain=2)
        # domain ids are row-major over the 4x4 grid
        assert fab.domain_distance(0, 1) == 1       # (0,0)-(0,1)
        assert fab.domain_distance(0, 3) == 1       # (0,0)-(0,3) wraps
        assert fab.domain_distance(0, 12) == 1      # (0,0)-(3,0) wraps
        assert fab.domain_distance(0, 10) == 4      # (0,0)-(2,2): 2+2
        assert fab.diameter() == 4                  # (2, 2) opposite corner

    def test_dragonfly_two_level_distances(self):
        fab = DragonflyFabric(n_groups=2, routers_per_group=3, nodes_per_router=2)
        assert fab.domain_distance(0, 1) == 1   # same group
        assert fab.domain_distance(0, 3) == 3   # across groups
        assert fab.distance_at_spread(3) == 1   # fits one group
        assert fab.distance_at_spread(4) == 3

    def test_clos_uniform_inter_pod(self):
        fab = ClosFabric([4, 4, 4])
        for a in range(3):
            for b in range(3):
                assert fab.domain_distance(a, b) == (0 if a == b else 2)


# ------------------------------------------------------------- fabric shape
class TestFabricStructure:
    @pytest.mark.parametrize("fab", sample_fabrics(), ids=lambda f: f.kind)
    def test_domain_index_consistent(self, fab):
        idx = fab.domain_index()
        assert len(idx) == fab.n_nodes
        for d in range(fab.n_domains):
            nodes = fab.domain_nodes(d)
            assert all(idx[n] == d for n in nodes)
        assert sum(len(fab.domain_nodes(d)) for d in range(fab.n_domains)) == fab.n_nodes

    @pytest.mark.parametrize("fab", sample_fabrics(), ids=lambda f: f.kind)
    def test_partition_covers(self, fab):
        ds = list(range(fab.n_domains))
        a, b = fab.partition(ds)
        assert sorted(a + b) == ds
        assert abs(len(a) - len(b)) <= 1

    @pytest.mark.parametrize("fab", sample_fabrics(), ids=lambda f: f.kind)
    def test_scheduling_blocks_partition(self, fab):
        blocks = fab.scheduling_blocks(2)
        flat = sorted(d for blk in blocks for d in blk)
        assert flat == list(range(fab.n_domains))
        assert all(1 <= len(blk) <= 2 for blk in blocks)

    def test_comparable_fabric_preserves_capacity(self):
        caps = [5, 7, 6, 6, 8, 4]
        for kind in ("clos", "rail-only", "torus", "dragonfly"):
            fab = comparable_fabric(kind, caps)
            assert fab.n_nodes == sum(caps), kind
            assert fab.n_domains == len(caps), kind
            got = sorted(len(fab.domain_nodes(d)) for d in range(fab.n_domains))
            assert got == sorted(caps), kind


# ------------------------------------------------------------- CLOS parity
class TestClosParity:
    def test_cluster_requires_exactly_one_source(self):
        with pytest.raises(ValueError):
            Cluster()
        with pytest.raises(ValueError):
            Cluster([4, 4], fabric=ClosFabric([4, 4]))

    def test_legacy_ctor_equals_from_fabric(self):
        a = Cluster([6, 5, 7])
        b = Cluster.from_fabric(ClosFabric([6, 5, 7]))
        assert a.n_domains == b.n_domains == a.n_minipods
        assert [p.node_ids for p in a.minipods] == [p.node_ids for p in b.minipods]
        assert all(
            a.nodes[n].minipod == b.nodes[n].minipod
            and a.nodes[n].rack == b.nodes[n].rack
            for n in a.nodes
        )
        np.testing.assert_array_equal(a.domain_index, b.domain_index)

    def test_minipod_accessors_alias_domain_accessors(self):
        c = Cluster([4, 4, 4])
        assert c.n_minipods == c.n_domains
        assert c.free_in_minipod(1) == c.free_in_domain(1)
        assert c.domain_of(5) == c.nodes[5].minipod

    def test_domain_of_matches_vectorize_lookup(self, small_comm):
        """Satellite 1: the precomputed-index gather equals the old
        per-cell np.vectorize Python lookup."""
        cluster = Cluster.paper_setting("i")
        rng = np.random.default_rng(3)
        for _ in range(20):
            nodes = rng.choice(cluster.n_nodes, size=small_comm.n_cells,
                               replace=False)
            p = Placement(small_comm, nodes.reshape(small_comm.shape), cluster)
            legacy = np.vectorize(lambda n: cluster.nodes[int(n)].minipod)(
                p.assignment
            )
            np.testing.assert_array_equal(p.domain_of(), legacy)
            np.testing.assert_array_equal(p.minipod_of(), legacy)

    def test_spread_parity_on_clos(self, small_comm):
        """Fabric-generic spread == legacy minipod spread for identical
        random placements on both construction paths."""
        legacy = Cluster.paper_setting("i")
        fabric = Cluster.from_fabric(
            ClosFabric([p.capacity for p in legacy.minipods])
        )
        rng = np.random.default_rng(7)
        for _ in range(20):
            nodes = rng.choice(legacy.n_nodes, size=small_comm.n_cells,
                               replace=False)
            a = nodes.reshape(small_comm.shape)
            pl = Placement(small_comm, a, legacy)
            pf = Placement(small_comm, a, fabric)
            assert max_spreads(pl) == max_spreads(pf)
            assert weighted_spread(pl, 0.3) == weighted_spread(pf, 0.3)

    @pytest.mark.parametrize("name", ["mip", "hier", "best-fit", "gpu-packing",
                                      "topo-aware", "random-fit"])
    def test_scheduler_parity_on_clos(self, small_comm, name):
        """Every scheduler produces identical spreads on the legacy ctor
        and the explicit clos-fabric ctor (acceptance criterion)."""
        legacy = Cluster.paper_setting("ii")
        fabric = Cluster.from_fabric(
            ClosFabric([p.capacity for p in legacy.minipods])
        )
        r1 = get_scheduler(name).schedule(
            ScheduleRequest(comm=small_comm, cluster=legacy, alpha=0.3, seed=0))
        r2 = get_scheduler(name).schedule(
            ScheduleRequest(comm=small_comm, cluster=fabric, alpha=0.3, seed=0))
        assert (r1.dp_spread, r1.pp_spread) == (r2.dp_spread, r2.pp_spread)

    def test_hop_diameter_on_clos_is_cross_pod_distance(self, small_comm):
        cluster = Cluster.uniform(2, 12)
        a = np.arange(small_comm.n_cells).reshape(small_comm.shape)
        p = Placement(small_comm, a, cluster)
        dp_s, pp_s = max_spreads(p)
        dp_h, pp_h = max_hop_diameters(p)
        assert (dp_h == 0) == (dp_s <= 1)
        assert dp_h in (0, 2) and pp_h in (0, 2)


# ------------------------------------------------------ distance_onehot prop
class TestDistanceOnehotPermutation:
    def test_permutation_invariance(self):
        """Eq. 3 is invariant under permuting group members AND under
        relabeling the one-hot positions (randomized)."""
        rng = np.random.default_rng(11)
        for _ in range(100):
            n, k = int(rng.integers(2, 16)), int(rng.integers(2, 10))
            assign = rng.integers(0, k, size=n)
            v = np.zeros((n, k))
            v[np.arange(n), assign] = 1
            base = distance_onehot(v)
            assert base == distance_onehot(v[rng.permutation(n)])
            assert base == distance_onehot(v[:, rng.permutation(k)])
            assert base == group_spread(assign)


# --------------------------------------------------------------- net models
class TestFabricNetModels:
    def test_dispatch_by_kind(self):
        assert isinstance(fabric_net_model(ClosFabric([4, 4])), ClosNetModel)
        assert isinstance(
            fabric_net_model(RailOnlyFabric([4, 4])), RailOnlyNetModel)
        assert isinstance(
            fabric_net_model(TorusFabric((2, 2), nodes_per_domain=2)),
            TorusNetModel)
        assert isinstance(
            fabric_net_model(DragonflyFabric(2, 2, 2)), DragonflyNetModel)

    def test_unknown_kind_gets_generic_model(self):
        class WeirdFabric(BaseFabric):
            kind = "weird"

            def domain_distance(self, a, b):
                return 0 if a == b else 1

            def diameter(self):
                return 1

        m = fabric_net_model(WeirdFabric([2, 2]))
        assert type(m) is FabricNetModel

    def test_clos_model_identical_to_legacy(self, small_comm):
        """ClosNetModel must be output-identical to the pre-fabric NetModel
        (bench_e2e parity on clos hinges on this)."""
        legacy = NetModel()
        fab = ClosNetModel(ClosFabric([8] * 8))
        for spread in range(0, 9):
            for size in (1e6, 64e6, 2e9):
                assert legacy.collective_busbw(size, spread) == pytest.approx(
                    fab.collective_busbw(size, spread))
                assert legacy.p2p_busbw(size, spread) == pytest.approx(
                    fab.p2p_busbw(size, spread))
        t1 = simulate_step_time(small_comm, 2, 1, net=legacy,
                                rng=np.random.default_rng(0))
        t2 = simulate_step_time(small_comm, 2, 1, net=fab,
                                rng=np.random.default_rng(0))
        assert t1.total == pytest.approx(t2.total)

    @pytest.mark.parametrize("fab,model_cls", [
        (RailOnlyFabric([8] * 8), RailOnlyNetModel),
        (TorusFabric((2, 4), nodes_per_domain=8), TorusNetModel),
        (DragonflyFabric(2, 4, 8), DragonflyNetModel),
    ], ids=["rail-only", "torus", "dragonfly"])
    def test_busbw_monotone_in_hops(self, fab, model_cls):
        """More hops never increases bandwidth under any fabric model."""
        m = model_cls(fab)
        size = 64e6
        prev_c = prev_p = None
        for hops in range(0, fab.diameter() + 1):
            c = m.collective_busbw(size, spread=2, hops=hops)
            p = m.p2p_busbw(size, spread=2, hops=hops)
            assert c > 0 and p > 0
            if prev_c is not None:
                assert c <= prev_c + 1e-9
                assert p <= prev_p + 1e-9
            prev_c, prev_p = c, p


# --------------------------------------------------------------- schedulers
class TestSchedulersOnFabrics:
    @pytest.mark.parametrize("kind", ["rail-only", "torus", "dragonfly"])
    def test_mip_and_hier_run_on_fabric(self, small_comm, kind):
        cluster = Cluster.from_fabric(comparable_fabric(kind, [8] * 8))
        for name in ("mip", "hier"):
            res = get_scheduler(name).schedule(
                ScheduleRequest(comm=small_comm, cluster=cluster, alpha=0.3))
            assert res.placement.assignment.shape == small_comm.shape
            assert res.dp_spread >= 0 and res.pp_spread >= 0

    def test_hier_blocks_follow_fabric(self, small_comm):
        """On dragonfly, hier's coarse blocks are the fabric's groups."""
        fab = DragonflyFabric(n_groups=4, routers_per_group=2, nodes_per_router=6)
        blocks = fab.scheduling_blocks(2)
        assert blocks == [[0, 1], [2, 3], [4, 5], [6, 7]]
        cluster = Cluster.from_fabric(fab)
        res = get_scheduler("hier").schedule(
            ScheduleRequest(comm=small_comm, cluster=cluster, alpha=0.3,
                            options={"pods_per_block": 2}))
        assert res.stats["n_blocks"] == 4
