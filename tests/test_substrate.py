"""Tests for optimizer, schedules, data pipeline, checkpointing, and the
fault-tolerant trainer."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import Checkpointer
from repro.configs import get_config
from repro.data import Prefetcher, SyntheticDataset
from repro.models import ModelOptions, build_model
from repro.optim import AdamWConfig, adamw_update, get_schedule, init_opt_state
from repro.train import FaultInjector, Trainer, TrainerConfig


class TestAdamW:
    def test_converges_on_quadratic(self):
        params = {"w": jnp.array([5.0, -3.0])}
        state = init_opt_state(params)
        cfg = AdamWConfig(lr=0.1, weight_decay=0.0, grad_clip=0.0)
        loss = lambda p: jnp.sum(jnp.square(p["w"]))
        for _ in range(300):
            g = jax.grad(loss)(params)
            params, state, m = adamw_update(params, g, state, cfg)
        assert float(loss(params)) < 1e-3

    def test_matches_reference_numpy(self):
        """One AdamW step vs a hand-written numpy reference."""
        w0 = np.array([1.0, -2.0, 0.5], np.float32)
        g = np.array([0.1, 0.2, -0.3], np.float32)
        lr, b1, b2, eps, wd = 1e-2, 0.9, 0.95, 1e-8, 0.1
        m = (1 - b1) * g
        v = (1 - b2) * g * g
        mhat, vhat = m / (1 - b1), v / (1 - b2)
        ref = w0 - lr * (mhat / (np.sqrt(vhat) + eps) + wd * w0)

        params = {"w": jnp.asarray(w0)}
        state = init_opt_state(params)
        cfg = AdamWConfig(lr=lr, b1=b1, b2=b2, eps=eps, weight_decay=wd, grad_clip=0.0)
        params, state, _ = adamw_update(params, {"w": jnp.asarray(g)}, state, cfg)
        np.testing.assert_allclose(np.asarray(params["w"]), ref, rtol=1e-6)

    def test_grad_clip(self):
        params = {"w": jnp.zeros(4)}
        state = init_opt_state(params)
        cfg = AdamWConfig(lr=0.0, grad_clip=1.0)
        big = {"w": jnp.full(4, 100.0)}
        _, _, m = adamw_update(params, big, state, cfg)
        assert float(m["grad_norm"]) == pytest.approx(200.0)  # pre-clip norm


class TestSchedules:
    def test_cosine_shape(self):
        f = get_schedule("cosine", 1e-3, 10, 100)
        assert float(f(0)) == 0.0
        assert float(f(10)) == pytest.approx(1e-3)
        assert float(f(100)) == pytest.approx(1e-4, rel=0.01)

    def test_wsd_shape(self):
        f = get_schedule("wsd", 1e-3, 10, 100)
        assert float(f(10)) == pytest.approx(1e-3)
        assert float(f(50)) == pytest.approx(1e-3)      # stable phase
        assert float(f(89)) == pytest.approx(1e-3)
        assert float(f(100)) == pytest.approx(1e-5, rel=0.01)  # decayed

    def test_unknown(self):
        with pytest.raises(ValueError):
            get_schedule("nope", 1e-3, 1, 2)


class TestData:
    def test_deterministic(self):
        ds = SyntheticDataset(vocab=128, seq_len=16, global_batch=4, seed=7)
        b1, b2 = ds.batch(3), ds.batch(3)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        b3 = ds.batch(4)
        assert not np.array_equal(b1["tokens"], b3["tokens"])

    def test_labels_are_next_tokens(self):
        ds = SyntheticDataset(vocab=128, seq_len=16, global_batch=2)
        b = ds.batch(0)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])

    def test_markov_predictable(self):
        """Markov stream entropy << uniform: bigram model predicts it."""
        ds = SyntheticDataset(vocab=64, seq_len=256, global_batch=8)
        b = ds.batch(0)
        # most frequent successor per token predicts well above chance
        succ = {}
        for row in b["tokens"]:
            for a, c in zip(row[:-1], row[1:]):
                succ.setdefault(int(a), []).append(int(c))
        hits = tot = 0
        for row in ds.batch(1)["tokens"]:
            for a, c in zip(row[:-1], row[1:]):
                if int(a) in succ:
                    vals, counts = np.unique(succ[int(a)], return_counts=True)
                    hits += int(vals[counts.argmax()] == int(c))
                    tot += 1
        assert hits / tot > 0.3  # chance is 1/64

    def test_prefetcher(self):
        ds = SyntheticDataset(vocab=32, seq_len=8, global_batch=2)
        pf = Prefetcher(ds, start_step=5)
        try:
            s, b = pf.next()
            assert s == 5
            s, b = pf.next()
            assert s == 6
        finally:
            pf.close()


class TestCheckpointer:
    def test_roundtrip(self, tmp_path):
        ck = Checkpointer(tmp_path)
        tree = {"a": jnp.arange(6).reshape(2, 3), "b": {"c": jnp.ones(4, jnp.bfloat16)}}
        ck.save(10, tree)
        out = ck.restore(jax.eval_shape(lambda: tree), step=10)
        np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
        assert out["b"]["c"].dtype == jnp.bfloat16

    def test_latest_and_gc(self, tmp_path):
        ck = Checkpointer(tmp_path, keep_last=2)
        tree = {"x": jnp.zeros(2)}
        for s in (1, 2, 3, 4):
            ck.save(s, tree)
        assert ck.steps() == [3, 4]
        assert ck.latest_step() == 4

    def test_async(self, tmp_path):
        ck = Checkpointer(tmp_path, use_async=True)
        ck.save(1, {"x": jnp.ones(8)})
        ck.wait()
        assert ck.latest_step() == 1

    def test_shape_mismatch_raises(self, tmp_path):
        ck = Checkpointer(tmp_path)
        ck.save(1, {"x": jnp.zeros(2)})
        with pytest.raises(ValueError):
            ck.restore({"x": jax.ShapeDtypeStruct((3,), jnp.float32)}, step=1)

    def test_missing_raises(self, tmp_path):
        ck = Checkpointer(tmp_path)
        with pytest.raises(FileNotFoundError):
            ck.restore({"x": jax.ShapeDtypeStruct((1,), jnp.float32)})


class TestTrainer:
    def _mk(self, tmp_path, fail_at=(), total=24):
        cfg = get_config("minicpm-2b").reduced()
        model = build_model(cfg, ModelOptions(compute_dtype="float32", remat=False))
        ds = SyntheticDataset(cfg.vocab, seq_len=16, global_batch=4)
        return Trainer(
            model, ds, AdamWConfig(lr=3e-3),
            ckpt_dir=tmp_path / "ckpt",
            cfg=TrainerConfig(total_steps=total, ckpt_every=8, log_every=4),
            fault_injector=FaultInjector(list(fail_at)),
        )

    def test_loss_decreases(self, tmp_path):
        tr = self._mk(tmp_path)
        tr.run()
        losses = tr.losses()
        assert losses[-1] < losses[0], losses

    def test_restart_after_failure(self, tmp_path):
        tr = self._mk(tmp_path, fail_at=[13])
        tr.run()
        events = [h for h in tr.history if h.get("event") == "restart"]
        assert len(events) == 1
        assert tr.ckpt.latest_step() == 24

    def test_restart_is_bit_exact(self, tmp_path):
        """A crashed-and-resumed run must produce the same final params as an
        uninterrupted one (deterministic data + checkpoint restart)."""
        tr1 = self._mk(tmp_path / "a", fail_at=[13], total=16)
        tr1.run()
        tr2 = self._mk(tmp_path / "b", total=16)
        tr2.run()
        # compare final checkpoints leaf-by-leaf
        import json, pathlib
        def load_all(d):
            p = pathlib.Path(d) / "step_16"
            man = json.loads((p / "manifest.json").read_text())["leaves"]
            return {k: np.load(p / v["file"]) for k, v in man.items()}
        a = load_all(tmp_path / "a" / "ckpt")
        b = load_all(tmp_path / "b" / "ckpt")
        assert a.keys() == b.keys()
        for k in a:
            np.testing.assert_array_equal(a[k], b[k], err_msg=k)
