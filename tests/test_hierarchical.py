"""Tests for the "hier" scale tier (DESIGN.md §8): block decomposition,
paper-setting parity, warm-start repair, cache behaviour, and the
queue/simulator churn path."""

import numpy as np
import pytest

from repro.core import (
    Cluster,
    FallbackChain,
    HierarchicalScheduler,
    JobSpec,
    QueuePolicy,
    ScheduleRequest,
    ScheduleResult,
    TraceSimulator,
    build_comm_matrix,
    get_scheduler,
    weighted_spread,
)

BIG = (104, 96)  # ~10k-node uniform cluster (9984 nodes)


def _fresh(**kw) -> HierarchicalScheduler:
    """A scheduler with its own cache (registry instance's cache persists
    across tests and would turn cold solves into hits)."""
    return HierarchicalScheduler(**kw)


def _valid(res: ScheduleResult, comm, cluster) -> None:
    ids = res.placement.node_ids()
    assert len(ids) == comm.n_cells == len(set(ids))
    assert all(cluster.is_free(n) for n in ids)


def big_job(model7b) -> JobSpec:
    return JobSpec(n_gpus=4096, tp=8, pp=8, model=model7b)  # 512 nodes


class TestRegistration:
    def test_registered_with_aliases(self):
        assert get_scheduler("hier").name == "hier"
        assert get_scheduler("hierarchical") is get_scheduler("hier")
        assert get_scheduler("scale") is get_scheduler("hier")

    def test_composes_in_fallback_chain(self, small_comm, cluster_i):
        res = FallbackChain("hier", "mip", "topo-aware").schedule(
            ScheduleRequest(comm=small_comm, cluster=cluster_i, alpha=0.3)
        )
        assert res.stats["served_by"] == "hier"
        _valid(res, small_comm, cluster_i)


class TestParity:
    """On paper-setting clusters (single block) hier must match flat mip."""

    def test_setting_i_spread_within_10pct(self, small_comm, cluster_i):
        mip = get_scheduler("mip").schedule(
            ScheduleRequest(comm=small_comm, cluster=cluster_i, alpha=0.3)
        )
        hier = _fresh().schedule(
            ScheduleRequest(comm=small_comm, cluster=cluster_i, alpha=0.3)
        )
        _valid(hier, small_comm, cluster_i)
        sm = weighted_spread(mip.placement, 0.3)
        sh = weighted_spread(hier.placement, 0.3)
        assert sh <= sm * 1.1

    def test_setting_iii_spread_within_10pct(self, model7b, cluster_iii):
        comm = build_comm_matrix(big_job(model7b))
        mip = get_scheduler("mip").schedule(
            ScheduleRequest(comm=comm, cluster=cluster_iii, alpha=0.3)
        )
        hier = _fresh().schedule(
            ScheduleRequest(comm=comm, cluster=cluster_iii, alpha=0.3)
        )
        _valid(hier, comm, cluster_iii)
        assert weighted_spread(hier.placement, 0.3) <= (
            weighted_spread(mip.placement, 0.3) * 1.1
        )
        assert hier.stats["n_blocks"] == 1  # degenerates to flat MILP


class TestDecomposition:
    def test_multi_block_valid_placement(self, small_comm):
        cluster = Cluster.uniform(8, 8)
        res = _fresh().schedule(ScheduleRequest(
            comm=small_comm, cluster=cluster, alpha=0.3,
            options={"pods_per_block": 2},
        ))
        _valid(res, small_comm, cluster)
        assert res.stats["n_blocks"] == 4
        assert 1 <= res.stats["blocks_touched"] <= 4
        assert res.method == "hier"

    def test_seam_group_straddles_blocks(self, model7b):
        # one 8-node group, blocks of one 6-node minipod: must straddle
        cluster = Cluster.uniform(2, 6)
        comm = build_comm_matrix(JobSpec(n_gpus=64, tp=8, pp=8, model=model7b))
        res = _fresh().schedule(ScheduleRequest(
            comm=comm, cluster=cluster, alpha=0.3,
            options={"pods_per_block": 1},
        ))
        _valid(res, comm, cluster)
        assert res.stats["blocks_touched"] == 2

    def test_10k_nodes_subsecond(self, model7b):
        cluster = Cluster.uniform(*BIG)
        comm = build_comm_matrix(big_job(model7b))
        res = _fresh().schedule(ScheduleRequest(
            comm=comm, cluster=cluster, alpha=0.3, time_budget=1.0,
        ))
        _valid(res, comm, cluster)
        assert res.solve_seconds < 1.0
        assert res.stats["n_blocks"] > 1


class TestWarmStart:
    def _cold_then_fail(self, model7b, cluster):
        comm = build_comm_matrix(big_job(model7b))
        sched = _fresh()
        cold = sched.schedule(ScheduleRequest(
            comm=comm, cluster=cluster, alpha=0.3, time_budget=1.0,
        ))
        victim = cold.placement.node_ids()[0]
        return sched, comm, cold, victim

    def test_repair_correctness(self, model7b):
        cluster = Cluster.uniform(*BIG)
        sched, comm, cold, victim = self._cold_then_fail(model7b, cluster)
        warm = sched.schedule(ScheduleRequest(
            comm=comm, cluster=cluster, alpha=0.3, time_budget=1.0,
            prev_placement=cold.placement,
            dirty_nodes=frozenset([victim]),
            excluded_nodes=frozenset([victim]),
        ))
        assert warm.method == "hier-warm"
        assert warm.stats["warm_start"] is True
        assert warm.stats["repaired"][0][0] == victim
        _valid(warm, comm, cluster)
        ids = set(warm.placement.node_ids())
        assert victim not in ids
        # only the failed node moved
        assert len(ids ^ set(cold.placement.node_ids())) == 2

    def test_repair_5x_faster_than_cold(self, model7b):
        cluster = Cluster.uniform(*BIG)
        sched, comm, cold, victim = self._cold_then_fail(model7b, cluster)
        warm = sched.schedule(ScheduleRequest(
            comm=comm, cluster=cluster, alpha=0.3, time_budget=1.0,
            prev_placement=cold.placement,
            dirty_nodes=frozenset([victim]),
            excluded_nodes=frozenset([victim]),
        ))
        assert warm.method == "hier-warm"
        assert warm.solve_seconds * 5 <= cold.solve_seconds

    def test_large_churn_falls_back_to_cold(self, model7b):
        cluster = Cluster.uniform(16, 16)
        comm = build_comm_matrix(
            JobSpec(n_gpus=1024, tp=8, pp=8, model=model7b))  # 128 nodes
        sched = _fresh()
        cold = sched.schedule(
            ScheduleRequest(comm=comm, cluster=cluster, alpha=0.3))
        dirty = frozenset(cold.placement.node_ids()[:9])  # > repair_max_dirty
        res = sched.schedule(ScheduleRequest(
            comm=comm, cluster=cluster, alpha=0.3,
            prev_placement=cold.placement, dirty_nodes=dirty,
            excluded_nodes=dirty,
        ))
        assert res.method != "hier-warm"
        assert not (set(res.placement.node_ids()) & dirty)

    def test_repair_max_dirty_knob(self, model7b):
        cluster = Cluster.uniform(16, 16)
        comm = build_comm_matrix(
            JobSpec(n_gpus=1024, tp=8, pp=8, model=model7b))
        sched = _fresh()
        cold = sched.schedule(
            ScheduleRequest(comm=comm, cluster=cluster, alpha=0.3))
        victim = cold.placement.node_ids()[0]
        res = sched.schedule(ScheduleRequest(
            comm=comm, cluster=cluster, alpha=0.3,
            prev_placement=cold.placement,
            dirty_nodes=frozenset([victim]),
            excluded_nodes=frozenset([victim]),
            options={"repair_max_dirty": 0},
        ))
        assert res.method != "hier-warm"

    def test_other_schedulers_ignore_warm_hint(self, small_comm, cluster_i):
        cold = get_scheduler("mip").schedule(
            ScheduleRequest(comm=small_comm, cluster=cluster_i, alpha=0.3))
        res = get_scheduler("mip").schedule(ScheduleRequest(
            comm=small_comm, cluster=cluster_i, alpha=0.3,
            prev_placement=cold.placement,
            dirty_nodes=frozenset(cold.placement.node_ids()[:1]),
        ))
        assert res.method in ("milp", "greedy-proven-optimal", "greedy-incumbent")


class TestCache:
    def test_second_identical_request_hits(self, model7b):
        cluster = Cluster.uniform(16, 16)
        comm = build_comm_matrix(
            JobSpec(n_gpus=1024, tp=8, pp=8, model=model7b))
        sched = _fresh()
        req = dict(comm=comm, cluster=cluster, alpha=0.3)
        first = sched.schedule(ScheduleRequest(**req))
        second = sched.schedule(ScheduleRequest(**req))
        assert first.method == "hier"
        assert second.method == "hier-cached"
        assert second.stats["cache"]["hit"] is True
        assert second.stats["cache"]["hits"] == 1
        _valid(second, comm, cluster)

    def test_use_cache_false_bypasses(self, model7b):
        cluster = Cluster.uniform(16, 16)
        comm = build_comm_matrix(
            JobSpec(n_gpus=1024, tp=8, pp=8, model=model7b))
        sched = _fresh()
        req = dict(comm=comm, cluster=cluster, alpha=0.3,
                   options={"use_cache": False})
        sched.schedule(ScheduleRequest(**req))
        again = sched.schedule(ScheduleRequest(**req))
        assert again.method == "hier"
        assert len(sched.cache) == 0

    def test_hit_rate_reported_in_stats(self, model7b):
        cluster = Cluster.uniform(16, 16)
        comm = build_comm_matrix(
            JobSpec(n_gpus=1024, tp=8, pp=8, model=model7b))
        sched = _fresh()
        req = dict(comm=comm, cluster=cluster, alpha=0.3)
        sched.schedule(ScheduleRequest(**req))
        res = sched.schedule(ScheduleRequest(**req))
        assert res.stats["cache"]["hit_rate"] == pytest.approx(0.5)


class TestChurnPath:
    """QueuePolicy.replan_lpj + TraceSimulator failures (DESIGN.md §8.2)."""

    def test_replan_requires_plan(self, small_comm):
        policy = QueuePolicy(Cluster.uniform(4, 8))
        with pytest.raises(ValueError, match="no planned LPJ"):
            policy.replan_lpj(dirty_nodes=frozenset([0]))

    def test_replan_repairs_reservation(self, small_comm):
        policy = QueuePolicy(Cluster.uniform(4, 8), scheduler=_fresh())
        policy.plan_lpj(small_comm, arrival=100.0, alpha=0.3)
        victim = next(iter(policy.reserved_nodes()))
        res = policy.replan_lpj(dirty_nodes=frozenset([victim]))
        assert res.method == "hier-warm"
        assert victim not in policy.reserved_nodes()
        assert len(policy.reserved_nodes()) == small_comm.n_cells

    def test_simulator_failure_triggers_replan(self, small_comm):
        policy = QueuePolicy(Cluster.uniform(4, 8), scheduler=_fresh())
        sim = TraceSimulator(policy, tick=60.0)
        # plan at t=0; fail one reserved node at t=50 (before arrival)
        res0 = policy.scheduler.schedule(ScheduleRequest(
            comm=small_comm, cluster=policy.cluster, alpha=0.3))
        victim = res0.placement.node_ids()[0]
        res = sim.run(
            [], t_end=300.0,
            lpj_plan=(small_comm, 200.0, 0.3, "pp"),
            plan_at=0.0,
            failures=[(50.0, victim)],
        )
        assert res.failed_nodes == [victim]
        assert res.lpj_replans == 1
        assert victim not in res.lpj_nodes
        assert len(res.lpj_nodes) == small_comm.n_cells

    def test_simulator_failure_outside_reservation_no_replan(self, small_comm):
        cluster = Cluster.uniform(4, 8)
        policy = QueuePolicy(cluster, scheduler=_fresh())
        sim = TraceSimulator(policy, tick=60.0)
        planned = policy.scheduler.schedule(ScheduleRequest(
            comm=small_comm, cluster=cluster, alpha=0.3))
        outside = [n for n in range(cluster.n_nodes)
                   if n not in planned.placement.node_ids()][0]
        res = sim.run(
            [], t_end=300.0,
            lpj_plan=(small_comm, 200.0, 0.3, "pp"),
            plan_at=0.0,
            failures=[(50.0, outside)],
        )
        assert res.failed_nodes == [outside]
        assert res.lpj_replans == 0
