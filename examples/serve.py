"""Continuous-batching serving demo on a reduced glm4-9b (GQA kv=2):
topology-aware replica placement via the scheduler registry, then a seeded
Poisson load served by the paged-KV-cache engine (repro.serve), reporting
tokens/sec and latency percentiles.

Run:  PYTHONPATH=src python examples/serve.py
"""

import jax

from repro.configs import get_config
from repro.core import Cluster
from repro.models import ModelOptions, build_model
from repro.serve import (
    EngineConfig,
    GenerationRequest,
    LoadGenConfig,
    ReplicaSpec,
    ServeEngine,
    generate_requests,
    place_replicas,
    run_benchmark,
)
from repro.serve.placement import serving_model_spec


def main():
    cfg = get_config("glm4-9b").reduced()

    # 1) serving replicas are placed like any other communication-group
    #    workload: through get_scheduler(...) with graceful fallback
    cluster = Cluster.uniform(4, 4)
    replicas = place_replicas(
        cluster, 2,
        ReplicaSpec(model=serving_model_spec(cfg), tp=8, pp=2, n_gpus=16),
        scheduler="mip,topo-aware",
    )
    for p in replicas.placements:
        print(f"replica {p.replica_id}: nodes {p.node_ids} via {p.method} "
              f"(pp_spread={p.result.pp_spread})")

    # 2) one replica's engine serves a seeded Poisson workload with
    #    mid-flight admission and page recycling
    model = build_model(cfg, ModelOptions(compute_dtype="float32", remat=False))
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, EngineConfig(
        max_batch=8, page_size=16, n_pages=48, max_blocks=4,
    ))
    requests = generate_requests(LoadGenConfig(
        seed=0, n_requests=16, rate_rps=150.0, vocab=cfg.vocab,
    ))
    report = run_benchmark(engine, requests)
    print(report.summary())

    # 3) sanity: everything finished, tokens in range, every page recycled
    results = engine.results
    assert len(results) == len(requests)
    assert all(len(r.tokens) == req.max_new_tokens
               for r, req in zip(results, requests))
    assert all(0 <= t < cfg.vocab for r in results for t in r.tokens)
    engine.cache.allocator.assert_all_free()
    assert engine.cache.allocator.n_free == engine.config.n_pages
    replicas.release()
    assert cluster.n_free == cluster.n_nodes
    print("OK")


if __name__ == "__main__":
    main()
