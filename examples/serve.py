"""Batched serving demo: prefill-free batched decode with KV cache on a
reduced glm4-9b (GQA kv=2), greedy sampling, measuring tokens/sec.

Run:  PYTHONPATH=src python examples/serve.py
"""

import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import ModelOptions, build_model


def main():
    cfg = get_config("glm4-9b").reduced()
    model = build_model(cfg, ModelOptions(compute_dtype="float32", remat=False))
    params = model.init(jax.random.PRNGKey(0))

    batch, max_len, gen = 8, 96, 64
    cache = model.init_cache(batch, max_len)
    step = jax.jit(model.decode_step, donate_argnums=(1,))

    # warm the compile, then generate greedily from a fixed prompt token
    tokens = jnp.full((batch, 1), 7, jnp.int32)
    logits, cache = step(params, cache, tokens)
    tokens = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)

    t0 = time.perf_counter()
    out = [tokens]
    for _ in range(gen - 1):
        logits, cache = step(params, cache, tokens)
        tokens = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out.append(tokens)
    jax.block_until_ready(tokens)
    dt = time.perf_counter() - t0

    seqs = jnp.concatenate(out, axis=1)
    print(f"generated {batch}x{gen-1} tokens in {dt:.2f}s "
          f"({batch*(gen-1)/dt:.0f} tok/s on CPU)")
    print("first sequence:", seqs[0, :24].tolist())
    assert bool(jnp.all(seqs >= 0)) and bool(jnp.all(seqs < cfg.vocab))
    print("OK")


if __name__ == "__main__":
    main()
