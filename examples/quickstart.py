"""Quickstart: train a small LM end-to-end on CPU with the public API.

Covers the full substrate in ~40 lines: config -> model -> synthetic data ->
AdamW + WSD schedule -> fault-tolerant trainer with checkpointing.  Run:

    PYTHONPATH=src python examples/quickstart.py
"""

import tempfile

from repro.configs import get_config
from repro.data import SyntheticDataset
from repro.models import ModelOptions, build_model
from repro.optim import AdamWConfig, get_schedule
from repro.train import Trainer, TrainerConfig


def main():
    cfg = get_config("minicpm-2b").reduced()   # llama-like, tied embeddings
    model = build_model(cfg, ModelOptions(compute_dtype="float32", remat=False))
    dataset = SyntheticDataset(cfg.vocab, seq_len=64, global_batch=8, seed=0)

    steps = 200
    schedule = get_schedule("wsd", peak_lr=3e-3, warmup_steps=10, total_steps=steps)
    opt = AdamWConfig(lr=schedule, weight_decay=0.01)

    with tempfile.TemporaryDirectory() as ckpt_dir:
        trainer = Trainer(
            model, dataset, opt, ckpt_dir=ckpt_dir,
            cfg=TrainerConfig(total_steps=steps, ckpt_every=50, log_every=20),
            on_step=lambda h: print(
                f"step {h['step']:4d}  loss {h['loss']:.4f}  "
                f"gnorm {h['grad_norm']:.2f}"
            ),
        )
        trainer.run()
        losses = trainer.losses()
        print(f"\nloss: {losses[0]:.3f} -> {losses[-1]:.3f} "
              f"({'improved' if losses[-1] < losses[0] else 'NO IMPROVEMENT'})")
        assert losses[-1] < losses[0], "loss must decrease on the Markov stream"
        print(f"checkpoints kept: {trainer.ckpt.steps()}")


if __name__ == "__main__":
    main()
