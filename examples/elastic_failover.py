"""Fault tolerance end to end, at both layers the paper cares about:

* training layer -- a node failure mid-run (injected exception) triggers
  checkpoint-restart; the resumed run is bit-identical to an uninterrupted
  one (deterministic data pipeline + atomic checkpoints);
* scheduling layer -- Appendix B's backup-node proposal: the FailureManager
  reserves per-minipod backups, promotes one on failure (spread unchanged),
  and falls back to local/cross-pod repair when backups run out.

Run:  PYTHONPATH=src python examples/elastic_failover.py
"""

import tempfile

from repro.core import (
    Cluster,
    FailureManager,
    FallbackChain,
    JobSpec,
    ModelSpec,
    ScheduleRequest,
    build_comm_matrix,
    max_spreads,
)
from repro.configs import get_config
from repro.data import SyntheticDataset
from repro.models import ModelOptions, build_model
from repro.optim import AdamWConfig
from repro.train import FaultInjector, Trainer, TrainerConfig


def training_layer():
    print("=== training layer: crash at step 30, auto-restart ===")
    cfg = get_config("granite-8b").reduced()
    model = build_model(cfg, ModelOptions(compute_dtype="float32", remat=False))
    ds = SyntheticDataset(cfg.vocab, seq_len=48, global_batch=4)
    with tempfile.TemporaryDirectory() as d:
        tr = Trainer(
            model, ds, AdamWConfig(lr=2e-3), ckpt_dir=d,
            cfg=TrainerConfig(total_steps=60, ckpt_every=20, log_every=15),
            fault_injector=FaultInjector([30]),
            on_step=lambda h: print(f"  step {h['step']} loss {h['loss']:.3f}"),
        )
        tr.run()
        restarts = [h for h in tr.history if h.get("event") == "restart"]
        print(f"restarts: {len(restarts)} ({restarts[0]['error']})")
        print(f"finished at checkpoint step {tr.ckpt.latest_step()}")
        assert tr.ckpt.latest_step() == 60 and len(restarts) == 1


def scheduling_layer():
    print("\n=== scheduling layer: backup-node promotion (Appendix B) ===")
    cluster = Cluster.uniform(4, 20)
    model = ModelSpec(name="7b", hidden=4096, layers=32, vocab=50304,
                      seq_len=2048, global_batch=512, d_ff=16384)
    comm = build_comm_matrix(JobSpec(n_gpus=32 * 8, tp=4, pp=4, model=model))
    # MILP first; degrade to topo-aware if it cannot produce a placement.
    scheduler = FallbackChain("mip", "topo-aware")
    res = scheduler.schedule(ScheduleRequest(comm=comm, cluster=cluster, alpha=0.3))
    cluster.allocate(res.placement.node_ids())
    print(f"placed 32 nodes via {res.method}, spreads={max_spreads(res.placement)}")

    fm = FailureManager(res.placement, cluster, backup_frac=0.1)
    print(f"backups reserved: {fm.backup_count()}")
    pods_with_backup = {p for p, b in fm.backups.items() if b}
    victims = [n for n in res.placement.node_ids()
               if cluster.nodes[n].minipod in pods_with_backup][:3]
    for v in victims:
        ev = fm.on_failure(v)
        print(f"  node {v} failed -> {ev.replacement} via {ev.kind}; "
              f"spreads now ({ev.dp_spread_after}, {ev.pp_spread_after})")
    assert all(e.kind in ("backup", "local", "cross-pod") for e in fm.events)
    print("repair events:", [e.kind for e in fm.events])

    # Constrained re-placement (new with the unified API): plan a fresh
    # placement that avoids every node that has ever failed, falling back
    # to topo-aware if the constrained MILP is infeasible.
    cluster.release(res.placement.node_ids())
    failed = frozenset(v for v in victims)
    re_res = scheduler.schedule(ScheduleRequest(
        comm=comm, cluster=cluster, alpha=0.3, excluded_nodes=failed,
    ))
    assert not (set(re_res.placement.node_ids()) & failed)
    print(f"re-placed around {len(failed)} failed nodes via {re_res.method}, "
          f"spreads={max_spreads(re_res.placement)}")


if __name__ == "__main__":
    training_layer()
    scheduling_layer()
    print("\nOK")
