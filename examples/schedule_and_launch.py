"""The paper's full flow, end to end on 64 fake devices:

  1. a CLOS cluster (8 minipods) + an LPJ spec (64 GPUs, TP=4, PP=2)
  2. communication matrix (Eq. 1) + affinity lookup (characterization DB)
  3. Arnold's MILP placement (Eq. 4-10) vs a naive packing baseline
  4. placement -> logical-rank device permutation -> JAX mesh
  5. verify the mesh's communication-group spread dropped (Eq. 3 on-mesh)
  6. run sharded pjit train steps on the Arnold mesh

Run:  PYTHONPATH=src python examples/schedule_and_launch.py
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=64"

import jax
import jax.numpy as jnp

from repro.core import (
    CharacterizationDB,
    Cluster,
    JobSpec,
    ModelSpec,
    ScheduleRequest,
    build_comm_matrix,
    get_scheduler,
    list_schedulers,
)
from repro.configs import get_config
from repro.data import SyntheticDataset
from repro.launch.mesh import make_arnold_mesh, mesh_group_spread
from repro.models import ModelOptions, build_model
from repro.optim import AdamWConfig, init_opt_state
from repro.parallel import sharding as shd
from repro.train import make_train_step

DEVICES_PER_POD = 16  # fake-device convention: contiguous id blocks = pods


def main():
    # -- 1. cluster + job ----------------------------------------------------
    cluster = Cluster.uniform(4, 2)        # 4 minipods x 2 nodes = 64 GPUs
    arch = get_config("minicpm-2b")
    mspec = ModelSpec(
        name=arch.name, hidden=arch.d_model, layers=arch.n_layers,
        vocab=arch.vocab, seq_len=64, global_batch=16, d_ff=arch.d_ff,
    )
    job = JobSpec(n_gpus=64, tp=4, pp=2, model=mspec)

    # -- 2. comm matrix + affinity -------------------------------------------
    comm = build_comm_matrix(job)
    alpha, beta, unit = CharacterizationDB().affinity_for(comm)
    print(f"comm matrix {comm.shape}; v_d={comm.v_d/2**20:.0f} MiB "
          f"v_p={comm.v_p/2**20:.1f} MiB; affinity alpha={alpha:.2f} unit={unit}")

    # -- 3. MILP placement vs baseline, via the unified scheduler API --------
    request = ScheduleRequest(comm=comm, cluster=cluster, alpha=alpha,
                              beta=beta, unit=unit)
    print(f"registered schedulers: {list_schedulers()}")
    res = get_scheduler("mip").schedule(request)
    base = get_scheduler("gpu-packing").schedule(request)
    print(f"Arnold spreads (dp, pp): ({res.dp_spread}, {res.pp_spread}) "
          f"[{res.method}, {res.solve_seconds*1e3:.1f} ms]")
    print(f"packing spreads (dp, pp): ({base.dp_spread}, {base.pp_spread})")

    # -- 4./5. mesh from the placement ---------------------------------------
    mesh = make_arnold_mesh(res.placement, tp=job.tp, shape=(8, 8),
                            axes=("data", "model"))
    naive = jax.make_mesh((8, 8), ("data", "model"))
    for name, m in [("arnold", mesh), ("naive", naive)]:
        print(f"{name} mesh: model-axis spread="
              f"{mesh_group_spread(m, 'model', DEVICES_PER_POD)}, "
              f"data-axis spread="
              f"{mesh_group_spread(m, 'data', DEVICES_PER_POD)}")

    # -- 6. sharded training steps on the Arnold mesh ------------------------
    cfg = arch.reduced()
    model = build_model(cfg, ModelOptions(remat=False))
    ds = SyntheticDataset(cfg.vocab, seq_len=64, global_batch=16)
    opt = AdamWConfig(lr=1e-3)
    params = model.init(jax.random.PRNGKey(0))
    state = init_opt_state(params)
    with shd.activate(mesh):
        stepper = make_train_step(model, opt, mesh=mesh, donate=False)
        batch0 = {k: jnp.asarray(v) for k, v in ds.batch(0).items()}
        fn = stepper(jax.eval_shape(lambda: batch0))
        for i in range(3):
            batch = {k: jnp.asarray(v) for k, v in ds.batch(i).items()}
            params, state, metrics = fn(params, state, batch)
            print(f"sharded step {i}: loss={float(metrics['loss']):.4f}")
    print("OK: scheduled, placed, and trained on the Arnold-aligned mesh")


if __name__ == "__main__":
    main()
